"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B; qwen1.5 arch, MHA + qkv bias]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=13440, vocab_size=92416, qkv_bias=True, rope_theta=1e6,
    micro_batches=8,
)

SMOKE = ModelConfig(
    name="codeqwen1.5-7b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, qkv_bias=True, attn_chunk=32,
    micro_batches=1,
)
