"""Architecture registry: one module per assigned architecture.

``get(name)`` returns the full published config; ``get_smoke(name)``
returns a reduced same-family config for CPU tests (small widths, few
experts, tiny vocab) — the full configs are exercised only through the
dry-run's ShapeDtypeStruct lowering.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.config import ModelConfig

ARCH_IDS: List[str] = [
    "qwen3_4b",
    "nemotron_4_340b",
    "codeqwen15_7b",
    "yi_34b",
    "internvl2_76b",
    "hymba_1_5b",
    "hubert_xlarge",
    "falcon_mamba_7b",
    "moonshot_v1_16b_a3b",
    "deepseek_v3_671b",
]

# CLI-facing ids (dashes) -> module names (underscores).
ALIASES: Dict[str, str] = {i.replace("_", "-"): i for i in ARCH_IDS}
ALIASES.update({
    "qwen3-4b": "qwen3_4b",
    "nemotron-4-340b": "nemotron_4_340b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "yi-34b": "yi_34b",
    "internvl2-76b": "internvl2_76b",
    "hymba-1.5b": "hymba_1_5b",
    "hubert-xlarge": "hubert_xlarge",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "deepseek-v3-671b": "deepseek_v3_671b",
})


def _module(name: str):
    mod = ALIASES.get(name, name)
    if mod not in ARCH_IDS and mod != "terapool":
        raise KeyError(f"unknown architecture {name!r}; "
                       f"available: {sorted(ALIASES)}")
    return importlib.import_module(f".{mod}", __package__)


def get(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _module(name).SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {i: get(i) for i in ARCH_IDS}
