"""InternVL2-76B [arXiv:2404.16821; InternViT frontend + LLaMA-70B-class
text backbone].

The vision frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed patch embeddings (256 tokens) that are spliced in
front of the token stream; only the transformer backbone is modeled.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256, rope_theta=5e5,
    frontend="vision", n_frontend_tokens=256,
    micro_batches=8, fsdp_serve=True, serve_2d_tp=True, seq_shard_acts=True,
)

SMOKE = ModelConfig(
    name="internvl2-76b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, frontend="vision", n_frontend_tokens=8,
    attn_chunk=32, micro_batches=1,
)
