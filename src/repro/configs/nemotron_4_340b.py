"""Nemotron-4-340B [arXiv:2402.16819; dense GQA + squared-ReLU].

Memory plan for 256 x 16 GiB (train_4k): ZeRO-3 over ``data`` x TP over
``model`` => bf16 params 2.7 GiB/chip + int8 channel-quantized moments
2.7 GiB + bf16 grad accumulation 2.7 GiB + seq-sharded rematerialized
activations at 16 grad-accumulation microbatches.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, head_dim=192,
    d_ff=73728, vocab_size=256000, act="relu2", rope_theta=1e4,
    micro_batches=16, fsdp_serve=True, serve_2d_tp=True, seq_shard_acts=True,
    master_dtype="bfloat16", moment_dtype="int8",
    grad_accum_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="nemotron-4-340b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=256, vocab_size=256, act="relu2", attn_chunk=32,
    micro_batches=1, moment_dtype="int8", grad_accum_dtype="bfloat16",
)
