"""The paper's own machine configuration (Layer-A simulator defaults)
plus the default SyncConfig mapping for Layer B."""
from ..core.collectives import SyncConfig
from ..core.topology import TeraPoolConfig

MACHINE = TeraPoolConfig()

# Default TPU-side synchronization config derived from the paper's best
# result (radix-32 tree + partial sync): hierarchical schedules with
# per-layer (overlappable) gradient sync.
SYNC = SyncConfig(mode="hierarchical", fsdp=True, overlap=True)
SYNC_BASELINE = SyncConfig(mode="flat", fsdp=False, overlap=False)
