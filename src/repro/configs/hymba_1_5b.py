"""Hymba-1.5B [arXiv:2411.13676; hybrid parallel attention + Mamba heads].

Hymba fuses attention and SSM heads in the SAME layer (parallel paths,
learned mixing).  We model all attention as sliding-window (w=1024) —
the sub-quadratic mixer is what qualifies this arch for the
``long_500k`` cell; the few global-attention layers of the release
checkpoint and the meta-tokens are noted as simplifications in
DESIGN.md.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001, ssm_state=16, attn_window=1024,
    rope_theta=1e4, micro_batches=8,
)

SMOKE = ModelConfig(
    name="hymba-1.5b-smoke", family="hybrid",
    n_layers=2, d_model=64, n_heads=5, n_kv_heads=1, head_dim=8,
    d_ff=128, vocab_size=256, ssm_state=8, attn_window=16,
    attn_chunk=16, micro_batches=1,
)
