"""HuBERT-XLarge [arXiv:2106.07447; audio encoder, w2v2 backbone].

Encoder-only: bidirectional attention, no decode shapes.  The conv
waveform frontend is a STUB: ``input_specs()`` provides precomputed
frame embeddings (B, S, d_model); the head predicts the 504-class
cluster vocabulary (masked-prediction training).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="encoder",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab_size=504, frontend="audio",
    micro_batches=8,
)

SMOKE = ModelConfig(
    name="hubert-xlarge-smoke", family="encoder",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=32, frontend="audio", attn_chunk=32,
    micro_batches=1,
)
