"""Moonshot-v1-16B-A3B [hf:moonshotai/Moonlight-16B-A3B; MoE 64e top-6].

Moonlight-style: leading dense layer, 64 routed experts (top-6) +
2 shared experts, GQA(kv=16 == MHA at 16 heads).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=11264, d_ff_expert=1408, vocab_size=163840,
    n_experts=64, top_k=6, n_shared_experts=2, n_dense_layers=1,
    rope_theta=5e4, micro_batches=8,
)

SMOKE = ModelConfig(
    name="moonshot-v1-16b-a3b-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, d_ff_expert=32, vocab_size=256,
    n_experts=8, top_k=2, n_shared_experts=2, n_dense_layers=1,
    attn_chunk=32, micro_batches=1,
)
