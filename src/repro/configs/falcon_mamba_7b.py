"""Falcon-Mamba-7B [arXiv:2410.05355; pure Mamba-1, attention-free].

Attention-sharding aspects of the paper's technique are inapplicable
(DESIGN.md §Arch-applicability): TP shards the SSM channel dimension
(d_inner) instead; the hierarchical gradient-sync schedules apply
unchanged.  O(1)-state decode qualifies the arch for ``long_500k``.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, vocab_size=65024,
    ssm_state=16, d_conv=4, expand=2, d_ff=0,
    micro_batches=8,
)

SMOKE = ModelConfig(
    name="falcon-mamba-7b-smoke", family="ssm",
    n_layers=2, d_model=64, vocab_size=256, ssm_state=8,
    d_conv=4, expand=2, d_ff=0, micro_batches=1,
)
