"""DeepSeek-V3-671B [arXiv:2412.19437; MLA + 1 shared / 256 routed
top-8 MoE + MTP].

Memory plan for 256 x 16 GiB (train_4k): bf16 params 5.2 GiB/chip +
int8 first moment 2.6 GiB + factored second moment (~0) + bf16 grad
accumulation 5.2 GiB; MLA latent decode cache is sequence-sharded.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=18432, d_ff_expert=2048, vocab_size=129280,
    n_experts=256, top_k=8, n_shared_experts=1, n_dense_layers=3,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    use_mtp=True, rope_theta=1e4,
    micro_batches=8, fsdp_serve=True, serve_2d_tp=True, seq_shard_acts=True,
    master_dtype="bfloat16", moment_dtype="int8",
    factored_second_moment=True, grad_accum_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="deepseek-v3-671b-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, d_ff_expert=32, vocab_size=256,
    n_experts=8, top_k=2, n_shared_experts=1, n_dense_layers=1,
    use_mla=True, q_lora_rank=32, kv_lora_rank=16,
    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
    use_mtp=True, attn_chunk=32, micro_batches=1,
    master_dtype="bfloat16", moment_dtype="int8",
    factored_second_moment=True, grad_accum_dtype="bfloat16",
)
