"""Yi-34B [arXiv:2403.04652; llama-arch GQA]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab_size=64000, rope_theta=5e6,
    micro_batches=8, seq_shard_acts=True,
)

SMOKE = ModelConfig(
    name="yi-34b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=7, n_kv_heads=1, head_dim=8,
    d_ff=128, vocab_size=256, attn_chunk=32, micro_batches=1,
)
