"""Shared building blocks: parameter registry, sharding helper, norms,
rotary embeddings and MLP variants.

Parameters are declared as :class:`ParamDef` pytrees carrying shape,
dtype, the tensor-parallel spec (``"model"`` axis positions) and the
FSDP dimension (the axis the ZeRO-3 gather/scatter runs over).  Both
real initialization and the dry-run's ``ShapeDtypeStruct`` stand-ins
derive from the same registry, so they can never diverge.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# Parameter registry.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declaration of one parameter tensor."""

    shape: Tuple[int, ...]
    tp: Tuple[Optional[str], ...]      # "model" on TP-sharded dims
    fsdp_dim: Optional[int] = 0        # dim the data-axis shard lives on
    dtype: str = "bfloat16"
    init: str = "normal"               # normal | zeros | ones | ssm_a | ssm_dt
    scale: float = 1.0                 # multiplier on the fan-in init

    def __post_init__(self):
        assert len(self.tp) == len(self.shape), (self.shape, self.tp)
        if self.fsdp_dim is not None:
            assert 0 <= self.fsdp_dim < len(self.shape)


def stacked(d: ParamDef, n_layers: int) -> ParamDef:
    """Stack a per-layer def along a leading scan axis."""
    return dataclasses.replace(
        d, shape=(n_layers,) + d.shape, tp=(None,) + d.tp,
        fsdp_dim=None if d.fsdp_dim is None else d.fsdp_dim + 1)


def init_param(key: jax.Array, d: ParamDef) -> jnp.ndarray:
    dtype = jnp.dtype(d.dtype)
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "ssm_a":
        # Mamba-1 A matrix: -log of 1..n repeated over channels, stored as
        # log(-A) so A = -exp(param).
        n = d.shape[-1]
        a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32),
                             d.shape)
        return jnp.log(a).astype(dtype)
    if d.init == "ssm_dt":
        # dt bias so softplus(dt) starts in [1e-3, 1e-1].
        u = jax.random.uniform(key, d.shape, jnp.float32, 1e-3, 1e-1)
        return (u + jnp.log(-jnp.expm1(-u))).astype(dtype)
    fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
    std = d.scale * (fan_in ** -0.5)
    return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(dtype)


def init_tree(key: jax.Array, defs) -> dict:
    """Initialize a full ParamDef pytree deterministically."""
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [init_param(k, d) for k, d in zip(keys, leaves)])


# ---------------------------------------------------------------------------
# Sharding helper.
# ---------------------------------------------------------------------------

# Batch-dim sharding axes: with_sharding_constraint is a FULL-spec hard
# constraint (a None entry forces replication of that dim), so every
# activation constraint must name the batch axes too.  constrain()
# drops whichever of these the ambient mesh lacks.
BATCH = ("pod", "data")


def constrain(x: jnp.ndarray, *spec) -> jnp.ndarray:
    """``with_sharding_constraint`` that silently drops axes that are not
    present (single-device smoke tests) or not Auto (manual shard_map
    axes), so model code is mesh-agnostic."""
    get_mesh = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_mesh is None:
        # jax < 0.5: no ambient abstract mesh to interrogate; leave
        # placement to the compiler (same as the empty-mesh case below).
        return x
    mesh = get_mesh()
    if mesh is None or mesh.empty:
        return x
    auto = {n for n, t in zip(mesh.axis_names, mesh.axis_types)
            if t == jax.sharding.AxisType.Auto}

    def clean(s):
        if s is None:
            return None
        if isinstance(s, (tuple, list)):
            kept = tuple(a for a in s if a in auto)
            return kept if kept else None
        return s if s in auto else None

    cleaned = tuple(clean(s) for s in spec)
    if all(c is None for c in cleaned):
        return x
    return jax.lax.with_sharding_constraint(x, P(*cleaned))


# ---------------------------------------------------------------------------
# Norms / activations / rotary embeddings.
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray,
             eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def swiglu(gate_up: jnp.ndarray) -> jnp.ndarray:
    gate, up = jnp.split(gate_up, 2, axis=-1)
    return jax.nn.silu(gate) * up


def relu2(h: jnp.ndarray) -> jnp.ndarray:
    """Squared ReLU (Nemotron-4)."""
    r = jax.nn.relu(h)
    return r * r


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)        # (..., S, 1, D/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP.
# ---------------------------------------------------------------------------

def mlp_defs(d_model: int, d_ff: int, act: str) -> dict:
    if act == "swiglu":
        return {
            "w_in": ParamDef((d_model, 2 * d_ff), (None, "model")),
            "w_out": ParamDef((d_ff, d_model), ("model", None), fsdp_dim=1),
        }
    if act == "relu2":
        return {
            "w_in": ParamDef((d_model, d_ff), (None, "model")),
            "w_out": ParamDef((d_ff, d_model), ("model", None), fsdp_dim=1),
        }
    raise ValueError(f"unknown activation {act!r}")


def mlp_apply(p: dict, x: jnp.ndarray, act: str,
              batch_axes=BATCH, tp_axes=("model",)) -> jnp.ndarray:
    h = x @ p["w_in"].astype(x.dtype)
    h = constrain(h, batch_axes, None, tp_axes)
    h = swiglu(h) if act == "swiglu" else relu2(h)
    return h @ p["w_out"].astype(x.dtype)
