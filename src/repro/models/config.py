"""Model/config schema for every supported architecture family.

One dataclass covers the assigned families (DESIGN.md §4): dense
GQA transformers, MoE (incl. MLA + MTP), pure SSM (Mamba-1), hybrid
attention+SSM, and encoder-only backbones with stub modality frontends.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encoder
    n_layers: int
    d_model: int
    vocab_size: int

    # --- attention ---
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0               # 0 -> d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_window: int = 0            # 0 -> full attention; else sliding window
    rope_theta: float = 1e6
    causal: bool = True             # False for encoder-only

    # --- MLP ---
    d_ff: int = 0
    act: str = "swiglu"             # swiglu | relu2

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_dense_layers: int = 0         # leading dense layers (DeepSeek: 3)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- MLA (DeepSeek) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (Mamba-1) ---
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                # 0 -> ceil(d_model / 16)

    # --- extras ---
    use_mtp: bool = False           # multi-token-prediction head
    mtp_loss_weight: float = 0.1
    frontend: str = "none"          # none | vision | audio (stub embeddings)
    n_frontend_tokens: int = 0      # prepended embedding positions (vision)
    tie_embeddings: bool = False

    # --- numerics / compile shape ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: bool = True
    attn_chunk: int = 1024          # flash-style block size (q and kv)

    # Mesh axes that shard the batch dim of activations (decode under
    # 2D-TP replicates activations instead: set to ()), and the axes
    # that shard activation feature dims (2D-TP: ("model", "data")).
    batch_axes: Tuple[str, ...] = ("pod", "data")
    tp_axes: Tuple[str, ...] = ("model",)

    # --- distribution knobs (per-arch defaults; overridable per run) ---
    fsdp_train: bool = True         # ZeRO-3 sharding of params over `data`
    fsdp_serve: bool = False        # gather-per-layer serving (huge models)
    serve_2d_tp: bool = False       # serve with TP over (model x data):
                                    # weights fully sharded, no per-layer
                                    # gathers (decode perf iteration)
    seq_shard_acts: bool = False    # Megatron-SP carry sharding: only the
                                    # >=70B archs need it (scan-carry HBM)
    moe_parallel: str = "ep"        # ep | tp
    micro_batches: int = 8          # grad-accumulation steps per train_step
    grad_accum_dtype: str = "float32"
    # optimizer state layout (distributed-optimization tricks)
    master_dtype: str = "float32"   # float32 | bfloat16 ("none" == bf16)
    moment_dtype: str = "float32"   # float32 | bfloat16 | int8
    factored_second_moment: bool = False

    def __post_init__(self):
        if self.family not in ("dense", "moe", "ssm", "hybrid", "encoder"):
            raise ValueError(f"unknown family {self.family!r}")
        if self.family != "ssm" and self.n_heads <= 0:
            raise ValueError("attention families need n_heads")
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.dt_rank == 0 and (self.family in ("ssm", "hybrid")):
            object.__setattr__(self, "dt_rank",
                               math.ceil(self.d_model / 16))
        if self.family == "encoder":
            object.__setattr__(self, "causal", False)

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.expand * self.d_model

    @property
    def n_rep(self) -> int:
        """GQA repetition factor."""
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def n_moe_layers(self) -> int:
        return self.n_layers - self.n_dense_layers if self.is_moe else 0

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Total parameter count (exact, matches param_defs)."""
        from . import transformer  # local import to avoid cycle
        import jax
        defs = transformer.param_defs(self)
        return sum(math.prod(d.shape) for d in jax.tree.leaves(
            defs, is_leaf=lambda x: hasattr(x, "shape")))

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: routed top-k + shared)."""
        if not self.is_moe:
            return self.param_count()
        total = self.param_count()
        # Remove inactive routed experts.
        expert = 3 * self.d_model * self.d_ff_expert
        inactive = (self.n_experts - self.top_k) * expert * self.n_moe_layers
        return total - inactive


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (architecture x input-shape) dry-run cell."""

    name: str                       # train_4k | prefill_32k | ...
    kind: str                       # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", 4096, 256),
    ShapeCell("prefill_32k", "prefill", 32768, 32),
    ShapeCell("decode_32k", "decode", 32768, 128),
    ShapeCell("long_500k", "decode", 524288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def applicable_shapes(cfg: ModelConfig) -> Tuple[ShapeCell, ...]:
    """Which of the four shape cells an architecture actually runs
    (DESIGN.md §4): encoder-only archs have no decode; ``long_500k``
    needs a sub-quadratic token mixer."""
    out = []
    for s in SHAPES:
        if cfg.family == "encoder" and s.kind == "decode":
            continue
        if (s.name == "long_500k"
                and not (cfg.has_ssm or cfg.attn_window > 0)):
            continue
        out.append(s)
    return tuple(out)


def skip_reason(cfg: ModelConfig, shape: ShapeCell) -> Optional[str]:
    if cfg.family == "encoder" and shape.kind == "decode":
        return "encoder-only: no autoregressive decode step"
    if shape.name == "long_500k" and not (cfg.has_ssm or cfg.attn_window):
        return "full quadratic attention: 524k-token decode infeasible"
    return None
