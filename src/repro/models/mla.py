"""Multi-head Latent Attention (DeepSeek-V3).

Train/prefill use the expanded path through :func:`flash_attention`
(qk dim = nope+rope, v dim = v_head_dim).  Decode uses the *absorbed*
path: queries are folded through the k up-projection so attention runs
directly against the (B, S, kv_lora) latent cache — the cache is ~9x
smaller than GQA's and is sequence-sharded over the ``model`` axis.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import BATCH, ParamDef, apply_rope, constrain, rms_norm
from .attention import NEG_INF, flash_attention


class MLACache(NamedTuple):
    ckv: jnp.ndarray        # (B, S_max, kv_lora) normalized latents
    kpe: jnp.ndarray        # (B, S_max, qk_rope_dim) roped shared key
    positions: jnp.ndarray  # (B, S_max) int32; -1 == empty


def mla_defs(cfg: ModelConfig) -> dict:
    h = cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "q_a": ParamDef((cfg.d_model, cfg.q_lora_rank), (None, None)),
        "q_a_norm": ParamDef((cfg.q_lora_rank,), (None,), fsdp_dim=None,
                             init="ones"),
        "q_b": ParamDef((cfg.q_lora_rank, h * qk), (None, "model")),
        "kv_a": ParamDef((cfg.d_model,
                          cfg.kv_lora_rank + cfg.qk_rope_dim),
                         (None, None)),
        "kv_a_norm": ParamDef((cfg.kv_lora_rank,), (None,), fsdp_dim=None,
                              init="ones"),
        "k_b": ParamDef((cfg.kv_lora_rank, h * cfg.qk_nope_dim),
                        (None, "model")),
        "v_b": ParamDef((cfg.kv_lora_rank, h * cfg.v_head_dim),
                        (None, "model")),
        "wo": ParamDef((h * cfg.v_head_dim, cfg.d_model),
                       ("model", None), fsdp_dim=1),
    }


def _latents(p, x, cfg, positions):
    """Shared (normalized latent, roped positional key) for the cache."""
    ckv_full = x @ p["kv_a"].astype(x.dtype)
    ckv, kpe = jnp.split(ckv_full, [cfg.kv_lora_rank], axis=-1)
    ckv = rms_norm(ckv, p["kv_a_norm"])
    kpe = apply_rope(kpe[:, :, None, :], positions,
                     cfg.rope_theta)[:, :, 0]
    return ckv, kpe


def _queries(p, x, cfg, positions):
    B, S, _ = x.shape
    h, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = rms_norm(x @ p["q_a"].astype(x.dtype), p["q_a_norm"])
    q = (cq @ p["q_b"].astype(x.dtype)).reshape(B, S, h, dn + dr)
    q = constrain(q, cfg.batch_axes, None, cfg.tp_axes, None)
    q_nope, q_pe = jnp.split(q, [dn], axis=-1)
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    return q_nope, q_pe


def mla_apply(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
              positions: jnp.ndarray,
              cache: Optional[MLACache] = None,
              decode_pos: Optional[jnp.ndarray] = None):
    """Returns (out, new_cache)."""
    B, S, _ = x.shape
    h, dn, dr, dv = (cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    scale = (dn + dr) ** -0.5
    dt = x.dtype

    if cache is not None and decode_pos is not None:
        # ---- absorbed decode ----
        from .attention import scatter_time
        ckv_new, kpe_new = _latents(p, x, cfg, positions)     # (B,1,..)
        Smax = cache.ckv.shape[1]
        slot = jnp.minimum(decode_pos[0], Smax - 1)
        new_cache = MLACache(
            ckv=scatter_time(cache.ckv, ckv_new, slot),
            kpe=scatter_time(cache.kpe, kpe_new, slot),
            positions=scatter_time(cache.positions[..., None],
                                   decode_pos[:, None, None],
                                   slot)[..., 0],
        )
        q_nope, q_pe = _queries(p, x, cfg, positions)
        k_b = p["k_b"].reshape(cfg.kv_lora_rank, h, dn)
        v_b = p["v_b"].reshape(cfg.kv_lora_rank, h, dv)
        # Absorb the k up-projection into the query.
        # bf16 einsums against the carried cache (f32 converts of the
        # cache get hoisted to a full f32 cache copy on XLA-CPU);
        # softmax runs in f32.
        q_lat = jnp.einsum("bhd,chd->bhc", q_nope[:, 0], k_b)  # (B,h,c)
        s = (jnp.einsum("bhc,bsc->bhs", q_lat.astype(new_cache.ckv.dtype),
                        new_cache.ckv)
             + jnp.einsum("bhr,bsr->bhs",
                          q_pe[:, 0].astype(new_cache.kpe.dtype),
                          new_cache.kpe)).astype(jnp.float32) * scale
        valid = ((new_cache.positions <= decode_pos[:, None])
                 & (new_cache.positions >= 0))
        s = jnp.where(valid[:, None], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhs,bsc->bhc", pr.astype(new_cache.ckv.dtype),
                         new_cache.ckv)
        out = jnp.einsum("bhc,chd->bhd", ctx.astype(dt), v_b.astype(dt))
        out = out.reshape(B, 1, h * dv).astype(dt)
    else:
        # ---- expanded train/prefill ----
        ckv, kpe = _latents(p, x, cfg, positions)
        new_cache = cache
        if cache is not None:
            Smax = cache.ckv.shape[1]
            span = min(S, Smax)

            def fill(buf, val):
                val = val[:, -span:].astype(buf.dtype)
                if span == Smax:
                    return val
                pad = [(0, 0), (0, Smax - span)] + [(0, 0)] * (val.ndim - 2)
                return jnp.pad(val, pad)

            pos_grid = jnp.broadcast_to(positions[..., -span:],
                                        (B, span)).astype(jnp.int32)
            if span < Smax:
                pos_grid = jnp.pad(pos_grid, [(0, 0), (0, Smax - span)],
                                   constant_values=-1)
            new_cache = MLACache(ckv=fill(cache.ckv, ckv),
                                 kpe=fill(cache.kpe, kpe),
                                 positions=pos_grid)
        q_nope, q_pe = _queries(p, x, cfg, positions)
        k_nope = (ckv @ p["k_b"].astype(dt)).reshape(B, S, h, dn)
        v = (ckv @ p["v_b"].astype(dt)).reshape(B, S, h, dv)
        k_nope = constrain(k_nope, cfg.batch_axes, None, cfg.tp_axes,
                           None)
        v = constrain(v, cfg.batch_axes, None, cfg.tp_axes, None)
        q = jnp.concatenate([q_nope, q_pe], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kpe[:, :, None, :],
                                      (B, S, h, dr)).astype(dt)], axis=-1)
        out = flash_attention(q, k, v, causal=cfg.causal,
                              chunk=cfg.attn_chunk, scale=scale)
        out = out.reshape(B, S, h * dv)

    out = constrain(out, cfg.batch_axes, None, cfg.tp_axes)
    return out @ p["wo"].astype(dt), new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> MLACache:
    return MLACache(
        ckv=jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        kpe=jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
        positions=jnp.full((batch, max_len), -1, jnp.int32),
    )
