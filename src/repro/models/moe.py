"""Mixture-of-Experts layer with expert parallelism.

Dispatch is *sort-based* (gather/scatter), not one-hot-einsum based: the
(T, E, C) dispatch tensor of the textbook formulation would dominate
both memory and — worse for the roofline's useful-FLOPs ratio — the
compiled FLOP count (T*E*C*d fake MACs per layer).  Sorting token
assignments by expert id costs O(Tk log Tk) scalar work and zero
matmul FLOPs.

Dispatch is **partially synchronized** (the paper's Group-barrier
analogue): a shard_map confines the sort/scatter to each data shard's
own tokens, so the token->expert exchange crosses only the ``model``
axis (where the experts live) and never the data axis.  Left global,
GSPMD replicates the (E*C_global, d) dispatch buffer on every chip —
18+ GiB/layer for DeepSeek-V3.  ``moe_parallel="tp"`` shards expert FFN
width instead of the expert dim (no all-to-all; a §Perf hillclimb axis).
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import BATCH, ParamDef, constrain, swiglu


def moe_defs(cfg: ModelConfig) -> dict:
    e, dm, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    ep = cfg.moe_parallel == "ep"
    etp = "model" if ep else None
    ftp = None if ep else "model"
    defs = {
        "router": ParamDef((dm, e), (None, None), fsdp_dim=None,
                           dtype="float32"),
        "w_in": ParamDef((e, dm, 2 * f), (etp, None, ftp), fsdp_dim=1),
        "w_out": ParamDef((e, f, dm), (etp, ftp, None), fsdp_dim=2),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        defs["shared_in"] = ParamDef((dm, 2 * fs), (None, "model"))
        defs["shared_out"] = ParamDef((fs, dm), ("model", None), fsdp_dim=1)
    return defs


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = math.ceil(n_tokens * cfg.top_k / cfg.n_experts
                  * cfg.capacity_factor)
    return max(4, -(-c // 4) * 4)   # round up to a multiple of 4


def _moe_local(p: dict, x: jnp.ndarray, cfg: ModelConfig
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Routed-expert compute on THIS data shard's tokens.
    x: (B_local, S, d) -> (out, aux_loss)."""
    B, S, d = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    C = _capacity(T, cfg)
    xt = x.reshape(T, d)

    # --- routing (fp32) ---
    logits = xt.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)                  # (T, E)
    gate, eidx = jax.lax.top_k(probs, K)                     # (T, K)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    # Load-balance auxiliary loss (Switch-style).
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(
        1.0 / (T * K))
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)

    # --- sort-based dispatch (local tokens only) ---
    e_flat = eidx.reshape(-1)                                # (T*K,)
    tok_flat = jnp.repeat(jnp.arange(T), K)
    w_flat = gate.reshape(-1)
    order = jnp.argsort(e_flat)
    e_s, tok_s, w_s = e_flat[order], tok_flat[order], w_flat[order]
    counts = jnp.zeros((E,), jnp.int32).at[e_flat].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(T * K, dtype=jnp.int32) - starts[e_s]
    keep = pos < C
    e_c = jnp.where(keep, e_s, E - 1)
    p_c = jnp.where(keep, pos, C - 1)

    # The (E, C, d) buffer lives expert-sharded on the model axis; the
    # scatter below IS the token->expert all-to-all.  The exchange runs
    # in f32 on the CPU backend (its AllReducePromotion pass crashes on
    # 16-bit reductions inside partial-manual regions); on TPU the
    # native dtype is kept.
    ep = cfg.moe_parallel == "ep"
    dd = jnp.float32 if jax.default_backend() == "cpu" else x.dtype
    xe = jnp.zeros((E, C, d), dd)
    xe = constrain(xe, "model" if ep else None, None, None)
    xe = xe.at[e_c, p_c].add(
        jnp.where(keep[:, None], xt[tok_s].astype(dd), 0))
    xe = constrain(xe, "model" if ep else None, None, None)

    # --- expert FFN (SwiGLU) ---
    h = jnp.einsum("ecd,edf->ecf", xe.astype(x.dtype),
                   p["w_in"].astype(x.dtype))
    h = constrain(h, "model" if ep else None, None,
                  None if ep else "model")
    h = swiglu(h)
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(x.dtype))
    ye = constrain(ye, "model" if ep else None, None, None)

    # --- combine (gather back to token order) ---
    gathered = ye.astype(dd)[e_c, p_c]
    gathered = jnp.where(keep[:, None], gathered, 0)
    out = jnp.zeros((T, d), dd).at[tok_s].add(
        gathered * w_s[:, None].astype(dd)).astype(x.dtype)

    return out.reshape(B, S, d), aux


def _dp_axes_for(x: jnp.ndarray, batch_axes=BATCH):
    get_mesh = getattr(jax.sharding, "get_abstract_mesh", None)
    mesh = get_mesh() if get_mesh is not None else None
    if mesh is None or mesh.empty:
        return (), None
    axes = tuple(
        a for a, t in zip(mesh.axis_names, mesh.axis_types)
        if a in batch_axes and t == jax.sharding.AxisType.Auto
        and mesh.shape[a] > 1)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if not axes or x.shape[0] % n:
        return (), None
    return axes, mesh


def _shared_experts(p: dict, x: jnp.ndarray, batch_axes=BATCH,
                    tp_axes=("model",)) -> jnp.ndarray:
    """Shared-expert FFN: plain TP matmuls, computed in the auto region
    (TP partial-sum all-reduces inside a partial-manual region trip the
    CPU backend's AllReducePromotion pass)."""
    hs = x @ p["shared_in"].astype(x.dtype)
    hs = constrain(hs, batch_axes, None, tp_axes)
    return swiglu(hs) @ p["shared_out"].astype(x.dtype)


def moe_apply(p: dict, x: jnp.ndarray, cfg: ModelConfig
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out, aux_loss).  Wraps the local dispatch in a
    data-axis shard_map (partial synchronization) when a mesh is
    available; single-device tests run the local path directly."""
    dp, mesh = _dp_axes_for(x, cfg.batch_axes)
    routed = {k: v for k, v in p.items()
              if k not in ("shared_in", "shared_out")}
    if dp and jax.default_backend() == "cpu":
        # Expert weights enter the manual region replicated over the DP
        # axes, so their cotangents psum over those axes INSIDE it; the
        # CPU backend miscompiles 16-bit manual-region reductions
        # (AllReducePromotion), so cross the boundary in f32 there.
        routed = jax.tree.map(
            lambda w: w.astype(jnp.float32)
            if w.dtype == jnp.bfloat16 else w, routed)
    if not dp:
        out, aux = _moe_local(routed, x, cfg)
    else:
        n_dp = 1
        for a in dp:
            n_dp *= mesh.shape[a]
        dp_e = dp if len(dp) > 1 else dp[0]

        def local(p_in, x_in):
            o, aux_l = _moe_local(p_in, x_in, cfg)
            for a in dp:
                aux_l = jax.lax.psum(aux_l, a)
            return o, aux_l / n_dp

        from ..core.collectives import shard_map_compat
        p_specs = jax.tree.map(lambda _: P(), routed)
        fn = shard_map_compat(local, mesh,
                              (p_specs, P(dp_e, None, None)),
                              (P(dp_e, None, None), P()), dp)
        out, aux = fn(routed, x)
    if "shared_in" in p:
        out = out + _shared_experts(p, x.reshape(out.shape),
                                    cfg.batch_axes, cfg.tp_axes)
    return out, aux
