"""Composable model stacks for every assigned architecture family.

One generic pre-norm residual block parameterized by the family:
  * dense:   x += attn(n1(x));  x += mlp(n2(x))
  * moe:     x += attn(n1(x));  x += moe(n2(x))      (+ leading dense)
  * ssm:     x += mamba(n1(x))
  * hybrid:  h = n1(x); x += g_a*attn(h) + g_s*mamba(h);  x += mlp(n2(x))
  * encoder: dense block, bidirectional attention

Layers are scanned (stacked parameters) with full rematerialization in
training, so the HLO stays one-layer-sized and activation memory is
bounded by the scan carries.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import mla as mla_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import ModelConfig
from .layers import (BATCH, ParamDef, constrain, init_tree, mlp_apply,
                     mlp_defs, rms_norm, stacked)


# ---------------------------------------------------------------------------
# Parameter registry.
# ---------------------------------------------------------------------------

def _norm_def(d: int) -> ParamDef:
    return ParamDef((d,), (None,), fsdp_dim=None, init="ones")


def block_defs(cfg: ModelConfig, *, moe_layer: bool = False) -> dict:
    d = cfg.d_model
    defs: Dict[str, Any] = {"norm1": _norm_def(d)}
    if cfg.family == "ssm":
        defs["ssm"] = ssm_mod.ssm_defs(cfg)
        return defs
    if cfg.use_mla:
        defs["attn"] = mla_mod.mla_defs(cfg)
    else:
        defs["attn"] = attn_mod.attn_defs(cfg)
    if cfg.family == "hybrid":
        defs["ssm"] = ssm_mod.ssm_defs(cfg)
        defs["gate_attn"] = ParamDef((1,), (None,), fsdp_dim=None,
                                     init="ones")
        defs["gate_ssm"] = ParamDef((1,), (None,), fsdp_dim=None,
                                    init="ones")
    defs["norm2"] = _norm_def(d)
    if moe_layer:
        defs["moe"] = moe_mod.moe_defs(cfg)
    else:
        defs["mlp"] = mlp_defs(d, cfg.d_ff, cfg.act)
    return defs


def param_defs(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    defs: Dict[str, Any] = {"final_norm": _norm_def(d)}
    if cfg.frontend != "audio":
        defs["embed"] = ParamDef((v, d), ("model", None), fsdp_dim=1,
                                 scale=d ** 0.5)  # ~N(0, 1/sqrt(d))
    defs["head"] = ParamDef((d, v), (None, "model"), fsdp_dim=0)

    def stack_tree(tree, n):
        return jax.tree.map(lambda pd: stacked(pd, n), tree,
                            is_leaf=lambda x: isinstance(x, ParamDef))

    if cfg.is_moe:
        if cfg.n_dense_layers:
            defs["dense_layers"] = stack_tree(
                block_defs(cfg, moe_layer=False), cfg.n_dense_layers)
        defs["layers"] = stack_tree(block_defs(cfg, moe_layer=True),
                                    cfg.n_moe_layers)
    else:
        defs["layers"] = stack_tree(block_defs(cfg), cfg.n_layers)

    if cfg.use_mtp:
        defs["mtp"] = {
            "proj": ParamDef((2 * d, d), (None, None)),
            "norm_h": _norm_def(d),
            "norm_e": _norm_def(d),
            "block": block_defs(cfg, moe_layer=False),
            "final_norm": _norm_def(d),
        }
    return defs


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    return init_tree(key, param_defs(cfg))


# ---------------------------------------------------------------------------
# Block application.
# ---------------------------------------------------------------------------

def block_apply(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                moe_layer: bool, positions: jnp.ndarray,
                cache: Optional[Any] = None,
                decode_pos: Optional[jnp.ndarray] = None):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    decode = decode_pos is not None
    if cfg.seq_shard_acts and not decode:
        x = constrain(x, cfg.batch_axes, "model", None)
    h = rms_norm(x, p["norm1"])

    new_cache = cache
    if cfg.family == "ssm":
        out, nc = ssm_mod.ssm_apply(p["ssm"], h, cfg, cache=cache,
                                    decode=decode)
        new_cache = nc if cache is not None else None
        x = x + out
    else:
        cache_attn = cache["attn"] if isinstance(cache, dict) else cache
        if cfg.use_mla:
            a_out, c_attn = mla_mod.mla_apply(
                p["attn"], h, cfg, positions=positions, cache=cache_attn,
                decode_pos=decode_pos)
        else:
            a_out, c_attn = attn_mod.attention_apply(
                p["attn"], h, cfg, positions=positions, cache=cache_attn,
                decode_pos=decode_pos)
        if cfg.family == "hybrid":
            s_out, c_ssm = ssm_mod.ssm_apply(p["ssm"], h, cfg,
                                             cache=cache["ssm"]
                                             if isinstance(cache, dict)
                                             else None,
                                             decode=decode)
            x = (x + p["gate_attn"].astype(x.dtype) * a_out
                 + p["gate_ssm"].astype(x.dtype) * s_out)
            new_cache = ({"attn": c_attn, "ssm": c_ssm}
                         if cache is not None else None)
        else:
            x = x + a_out
            new_cache = c_attn
        if cfg.seq_shard_acts and not decode:
            x = constrain(x, cfg.batch_axes, "model", None)
        h2 = rms_norm(x, p["norm2"])
        if moe_layer:
            m_out, aux = moe_mod.moe_apply(p["moe"], h2, cfg)
        else:
            m_out = mlp_apply(p["mlp"], h2, cfg.act,
                              cfg.batch_axes, cfg.tp_axes)
        x = x + m_out
    if cfg.seq_shard_acts and not decode:
        # Constrain the block OUTPUT too: this is the tensor the scan
        # saves as a residual for the backward pass — left replicated it
        # would dominate HBM (L x (B,S,d) per microbatch).
        x = constrain(x, cfg.batch_axes, "model", None)
    return x, new_cache, aux


def _scan_blocks(stack_p, x, cfg, *, moe_layer, positions, caches,
                 decode_pos, remat: bool, gather_fn=None):
    """lax.scan over a stacked block-parameter tree.  ``gather_fn``
    (ZeRO-3) all-gathers one layer's parameter shards just before use;
    with remat the gather is replayed in the backward pass, which is
    exactly the ZeRO-3 memory/traffic trade."""

    def body(carry, xs):
        xc, aux_acc = carry
        layer_p, cache = xs
        if gather_fn is not None:
            layer_p = gather_fn(layer_p)
        xc, new_cache, aux = block_apply(
            layer_p, xc, cfg, moe_layer=moe_layer, positions=positions,
            cache=cache, decode_pos=decode_pos)
        return (xc, aux_acc + aux), new_cache

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                        (stack_p, caches))
    return x, aux, new_caches


# ---------------------------------------------------------------------------
# Full forward.
# ---------------------------------------------------------------------------

def embed_inputs(params: dict, cfg: ModelConfig, batch: Dict[str, Any],
                 compute_dtype) -> jnp.ndarray:
    """Token/frontend embedding.  Audio: precomputed frame embeddings;
    vision: stub patch embeddings spliced in front of the token stream."""
    if cfg.frontend == "audio":
        return batch["features"].astype(compute_dtype)
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)
    if cfg.frontend == "vision" and "img_embeds" in batch:
        n = cfg.n_frontend_tokens
        img = batch["img_embeds"].astype(compute_dtype)
        x = jnp.concatenate([img, x[:, n:]], axis=1)
    return x


def forward(params: dict, cfg: ModelConfig, batch: Dict[str, Any], *,
            caches: Optional[Any] = None,
            decode_pos: Optional[jnp.ndarray] = None,
            remat: Optional[bool] = None,
            gather_fns: Optional[Dict[str, Any]] = None):
    """Run the stack.  Returns (logits, new_caches, aux, hidden).

    ``gather_fns`` (ZeRO-3): {"top": fn, "layers": fn, "dense_layers":
    fn} applied to parameter subtrees before use.
    """
    gather_fns = gather_fns or {}
    if "top" in gather_fns:
        top = {k: v for k, v in params.items()
               if k not in ("layers", "dense_layers")}
        params = {**params, **gather_fns["top"](top)}
    cdt = jnp.dtype(cfg.compute_dtype)
    x = embed_inputs(params, cfg, batch, cdt)
    B, S = x.shape[:2]
    if decode_pos is not None:
        positions = decode_pos[:, None]
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    remat = cfg.remat if remat is None else remat

    none_caches = caches is None

    def cache_for(name):
        # None is a valid empty pytree: scan zips it with stacked params.
        return None if none_caches else caches[name]

    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {}
    if cfg.is_moe and cfg.n_dense_layers:
        x, aux, nc = _scan_blocks(params["dense_layers"], x, cfg,
                                  moe_layer=False, positions=positions,
                                  caches=cache_for("dense_layers"),
                                  decode_pos=decode_pos, remat=remat,
                                  gather_fn=gather_fns.get("dense_layers"))
        aux_total += aux
        new_caches["dense_layers"] = nc
    x, aux, nc = _scan_blocks(params["layers"], x, cfg,
                              moe_layer=cfg.is_moe, positions=positions,
                              caches=cache_for("layers"),
                              decode_pos=decode_pos, remat=remat,
                              gather_fn=gather_fns.get("layers"))
    aux_total += aux
    new_caches["layers"] = nc

    if cfg.seq_shard_acts and decode_pos is None:
        # Un-shard the sequence before the vocab projection: the head
        # contraction must not mix a seq-sharded operand with the
        # vocab-sharded weight (GSPMD would otherwise materialize FULL
        # unsharded f32 copies of embed/head in the backward pass).
        x = constrain(x, cfg.batch_axes, None, None)
    hidden = rms_norm(x, params["final_norm"])
    logits = _project_logits(params, hidden)
    return logits, (None if none_caches else new_caches), aux_total, hidden


def _project_logits(params: dict, hidden: jnp.ndarray) -> jnp.ndarray:
    # NB: same-dtype operands; asking XLA-CPU for f32 accumulation here
    # materializes f32 CONVERTS of the (d,V) weight whose sharding the
    # partitioner then drops (full 17 GiB replicas for a 256k vocab).
    # The f32 cast happens on the (much smaller) sharded logits instead.
    logits = jnp.einsum("bsd,dv->bsv", hidden,
                        params["head"].astype(hidden.dtype))
    return constrain(logits, BATCH, None,
                     "model").astype(jnp.float32)  # train-path only


# ---------------------------------------------------------------------------
# Losses.
# ---------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """Mean CE over all positions; logits may be vocab-sharded."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, targets[..., None],
                                 axis=-1)[..., 0]
    return jnp.mean(lse - picked)


def mtp_loss(params: dict, cfg: ModelConfig, batch: Dict[str, Any],
             hidden: jnp.ndarray) -> jnp.ndarray:
    """DeepSeek multi-token-prediction: predict x_{t+2} from h_t and
    emb(x_{t+1}) through one extra block with the shared head."""
    p = params["mtp"]
    tokens, targets = batch["tokens"], batch["targets"]
    B, S = tokens.shape
    h_in = rms_norm(hidden[:, :S - 1], p["norm_h"])
    e_in = rms_norm(
        jnp.take(params["embed"], tokens[:, 1:], axis=0
                 ).astype(hidden.dtype), p["norm_e"])
    x = jnp.concatenate([h_in, e_in], axis=-1) @ p["proj"].astype(
        hidden.dtype)
    positions = jnp.broadcast_to(jnp.arange(S - 1)[None], (B, S - 1))
    x, _, _ = block_apply(p["block"], x, cfg, moe_layer=False,
                          positions=positions)
    x = rms_norm(x, p["final_norm"])
    logits = _project_logits(params, x)
    return cross_entropy(logits[:, :-1], targets[:, 1:-1])


def loss_fn(params: dict, cfg: ModelConfig, batch: Dict[str, Any],
            gather_fns: Optional[Dict[str, Any]] = None
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    if gather_fns and "top" in gather_fns:
        top = {k: v for k, v in params.items()
               if k not in ("layers", "dense_layers")}
        params = {**params, **gather_fns["top"](top)}
        gather_fns = {k: v for k, v in gather_fns.items() if k != "top"}
    logits, _, aux, hidden = forward(params, cfg, batch,
                                     gather_fns=gather_fns)
    ce = cross_entropy(logits, batch["targets"])
    loss = ce + aux
    metrics = {"ce": ce, "aux": aux}
    if cfg.use_mtp:
        m = mtp_loss(params, cfg, batch, hidden)
        loss = loss + cfg.mtp_loss_weight * m
        metrics["mtp"] = m
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# Cache construction.
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16):
    """Stacked per-layer decode caches for the whole model."""

    def one(moe_block: bool):
        del moe_block
        if cfg.family == "ssm":
            return ssm_mod.init_ssm_cache(cfg, batch, dtype)
        if cfg.use_mla:
            return mla_mod.init_mla_cache(cfg, batch, max_len, dtype)
        kv = attn_mod.init_cache(cfg, batch, max_len, dtype)
        if cfg.family == "hybrid":
            return {"attn": kv, "ssm": ssm_mod.init_ssm_cache(cfg, batch,
                                                              dtype)}
        return kv

    def stack_c(c, n):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), c)

    out = {}
    if cfg.is_moe and cfg.n_dense_layers:
        out["dense_layers"] = stack_c(one(False), cfg.n_dense_layers)
    out["layers"] = stack_c(one(cfg.is_moe),
                            cfg.n_moe_layers if cfg.is_moe else cfg.n_layers)
    return out
