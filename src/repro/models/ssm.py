"""Mamba-1 selective state-space block (Falcon-Mamba / Hymba SSM path).

The sequence recurrence runs as a chunked ``lax.scan``: the carry is the
(B, d_inner, n) SSM state, channels TP-sharded over ``model``.  Decode
keeps (conv_state, ssm_state) — O(1) in sequence length, which is what
makes the ``long_500k`` cell tractable for the SSM/hybrid archs.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import BATCH, ParamDef, constrain


class SSMCache(NamedTuple):
    conv: jnp.ndarray    # (B, d_conv-1, d_inner) last inputs
    state: jnp.ndarray   # (B, d_inner, n) SSM state


def ssm_defs(cfg: ModelConfig) -> dict:
    dm, di, n, r = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    return {
        "in_proj": ParamDef((dm, 2 * di), (None, "model")),
        "conv_w": ParamDef((cfg.d_conv, di), (None, "model"),
                           fsdp_dim=None, scale=1.0),
        "conv_b": ParamDef((di,), ("model",), fsdp_dim=None, init="zeros"),
        "x_proj": ParamDef((di, r + 2 * n), ("model", None), fsdp_dim=None),
        "dt_proj": ParamDef((r, di), (None, "model"), fsdp_dim=None),
        "dt_bias": ParamDef((di,), ("model",), fsdp_dim=None, init="ssm_dt"),
        "a_log": ParamDef((di, n), ("model", None), fsdp_dim=None,
                          init="ssm_a"),
        "d_skip": ParamDef((di,), ("model",), fsdp_dim=None, init="ones"),
        "out_proj": ParamDef((di, dm), ("model", None), fsdp_dim=1),
    }


def _ssm_params(p, x):
    """Input-dependent (dt, B, C) for x: (..., di)."""
    f32 = jnp.float32
    dbc = x @ p["x_proj"].astype(x.dtype)
    r = p["dt_proj"].shape[0]
    n = p["a_log"].shape[1]
    dt, b, c = jnp.split(dbc, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(f32) @ p["dt_proj"].astype(f32)
                         + p["dt_bias"].astype(f32))         # (..., di)
    return dt, b.astype(f32), c.astype(f32)


def _causal_conv(p, x, conv_state=None):
    """Depthwise causal conv over S.  x: (B,S,di)."""
    dw = p["conv_w"].astype(jnp.float32)                      # (K, di)
    K = dw.shape[0]
    xf = x.astype(jnp.float32)
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), jnp.float32)
    else:
        pad = conv_state.astype(jnp.float32)
    xp = jnp.concatenate([pad, xf], axis=1)                   # (B,S+K-1,di)
    out = sum(xp[:, i:i + x.shape[1]] * dw[i] for i in range(K))
    out = out + p["conv_b"].astype(jnp.float32)
    new_state = xp[:, -(K - 1):]
    return out.astype(x.dtype), new_state.astype(x.dtype)


def ssm_scan(p: dict, xc: jnp.ndarray, state: jnp.ndarray,
             chunk: int = 256) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Run the selective-scan recurrence over S.

    xc: (B,S,di) post-conv activations; state: (B,di,n).
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t;  y_t = C_t . h_t + D x_t.
    Scanned chunk-by-chunk (sequential outer scan, dense inner compute)
    to keep the HLO small for 4k-32k sequences.
    """
    B, S, di = xc.shape
    n = state.shape[-1]
    A = -jnp.exp(p["a_log"].astype(jnp.float32))              # (di, n)
    dt, bmat, cmat = _ssm_params(p, xc)                       # (B,S,..)
    x_f = xc.astype(jnp.float32)

    c = min(chunk, S)
    if S % c:
        c = S
    nchunks = S // c

    def chunk_step(h, idx):
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, idx * c, c, 1)
        dt_c, b_c, c_c, x_c = sl(dt), sl(bmat), sl(cmat), sl(x_f)
        # Per-step decay/input within the chunk, then a first-order
        # associative scan over time.
        decay = jnp.exp(dt_c[..., None] * A)                  # (B,c,di,n)
        inp = (dt_c * x_c)[..., None] * b_c[:, :, None, :]    # (B,c,di,n)

        def comb(a, b):
            (d1, u1), (d2, u2) = a, b
            return d1 * d2, u1 * d2 + u2

        dacc, uacc = jax.lax.associative_scan(comb, (decay, inp), axis=1)
        h_seq = dacc * h[:, None] + uacc                      # (B,c,di,n)
        y = jnp.einsum("bcdn,bcn->bcd", h_seq, c_c)
        return h_seq[:, -1], y

    if nchunks == 1:
        state, y = chunk_step(state, 0)
    else:
        state, ys = jax.lax.scan(chunk_step, state, jnp.arange(nchunks))
        y = ys.transpose(1, 0, 2, 3).reshape(B, S, di)
    y = y + x_f * p["d_skip"].astype(jnp.float32)
    return y.astype(xc.dtype), state


def ssm_apply(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
              cache: Optional[SSMCache] = None,
              decode: bool = False):
    """Full Mamba block.  Returns (out, new_cache)."""
    B, S, _ = x.shape
    dt = x.dtype
    xz = x @ p["in_proj"].astype(dt)
    xz = constrain(xz, cfg.batch_axes, None, cfg.tp_axes)
    xin, z = jnp.split(xz, 2, axis=-1)                        # (B,S,di)

    conv_state = cache.conv if cache is not None else None
    xc, new_conv = _causal_conv(p, xin, conv_state)
    xc = jax.nn.silu(xc)

    state = (cache.state if cache is not None else
             jnp.zeros((B, cfg.d_inner, cfg.ssm_state), jnp.float32))
    state = constrain(state, cfg.batch_axes, cfg.tp_axes, None)
    if decode:
        # Single-step recurrence (S == 1).
        A = -jnp.exp(p["a_log"].astype(jnp.float32))
        dtv, bv, cv = _ssm_params(p, xc[:, 0])                # (B, di/..)
        decay = jnp.exp(dtv[..., None] * A)
        state = decay * state + (dtv * xc[:, 0].astype(jnp.float32)
                                 )[..., None] * bv[:, None, :]
        y = jnp.einsum("bdn,bn->bd", state, cv)
        y = y + xc[:, 0].astype(jnp.float32) * p["d_skip"].astype(
            jnp.float32)
        y = y[:, None].astype(dt)
    else:
        y, state = ssm_scan(p, xc, state)

    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(dt)
    new_cache = SSMCache(conv=new_conv, state=state)
    return out, new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int,
                   dtype=jnp.bfloat16) -> SSMCache:
    return SSMCache(
        conv=jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
        state=jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    )
