"""Attention: GQA projections + flash-style chunked attention (train /
prefill), sliding-window fast path, and single-token decode against a
sequence-sharded KV cache.

Memory discipline matters more than elegance here: every path bounds
its live score block to ``(B, H, chunk, chunk)`` so 32k-token prefills
and 340B-parameter configs lower within a 16 GiB HBM budget.  The
decode cache is sharded over the ``model`` axis on the *sequence*
dimension (flash-decode style): kv-head counts rarely divide the TP
axis, sequence length always does.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import BATCH, ParamDef, apply_rope, constrain, rms_norm

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jnp.ndarray          # (B, S_max, Hk, D)  [rolling buffer if window]
    v: jnp.ndarray          # (B, S_max, Hk, D)
    positions: jnp.ndarray  # (B, S_max) int32; -1 marks empty slots


def attn_defs(cfg: ModelConfig) -> dict:
    h, hk, d, dm = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    defs = {
        "wq": ParamDef((dm, h * d), (None, "model")),
        "wk": ParamDef((dm, hk * d), (None, "model")),
        "wv": ParamDef((dm, hk * d), (None, "model")),
        "wo": ParamDef((h * d, dm), ("model", None), fsdp_dim=1),
    }
    if cfg.qkv_bias:
        defs |= {
            "bq": ParamDef((h * d,), ("model",), fsdp_dim=None, init="zeros"),
            "bk": ParamDef((hk * d,), ("model",), fsdp_dim=None,
                           init="zeros"),
            "bv": ParamDef((hk * d,), ("model",), fsdp_dim=None,
                           init="zeros"),
        }
    if cfg.qk_norm:
        defs |= {
            "q_norm": ParamDef((d,), (None,), fsdp_dim=None, init="ones"),
            "k_norm": ParamDef((d,), (None,), fsdp_dim=None, init="ones"),
        }
    return defs


# ---------------------------------------------------------------------------
# Flash-style chunked attention.
# ---------------------------------------------------------------------------

def _block_attn(qc, kc, vc, mask, scale):
    """One (q-block x kv-block) tile.  qc: (B,cq,Hk,g,D); kc/vc:
    (B,ck,Hk,D|Dv); mask: (cq,ck) or None.  Returns unnormalized
    (acc, m, l) contributions.  bf16 inputs with f32 accumulation
    (MXU-native; avoids materializing f32 copies of q/k/v)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kc,
                   preferred_element_type=jnp.float32) * scale
    if mask is not None:
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                  # (B,Hk,g,cq)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(jnp.isfinite(m)[..., None], p, 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc,
                     preferred_element_type=jnp.float32)
    return acc, m, l


def _combine(acc1, m1, l1, acc2, m2, l2):
    m = jnp.maximum(m1, m2)
    a1, a2 = jnp.exp(m1 - m), jnp.exp(m2 - m)
    a1 = jnp.where(jnp.isfinite(m1), a1, 0.0)
    a2 = jnp.where(jnp.isfinite(m2), a2, 0.0)
    return (acc1 * a1[..., None] + acc2 * a2[..., None],
            m, l1 * a1 + l2 * a2)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    chunk: int = 1024,
                    scale: Optional[float] = None) -> jnp.ndarray:
    """Chunked attention with running softmax.

    q: (B,S,H,D); k,v: (B,T,Hk,D[v]).  Sliding-window with
    ``window <= chunk`` touches only the diagonal and previous kv block
    (O(S) work); otherwise all kv blocks are scanned.
    """
    B, S, H, D = q.shape
    _, T, Hk, Dv = v.shape
    g = H // Hk
    scale = scale if scale is not None else D ** -0.5
    cq = ck = min(chunk, S, T)
    if S % cq or T % ck:  # small/odd shapes: single-block fallback
        cq, ck = S, T
    nq, nk = S // cq, T // ck
    qb = q.reshape(B, nq, cq, Hk, g, D)
    kb = k.reshape(B, nk, ck, Hk, D)
    vb = v.reshape(B, nk, ck, Hk, Dv)
    q_pos = jnp.arange(cq)
    k_pos = jnp.arange(ck)

    swa_fast = (window > 0 and window <= ck and nk == nq)

    def mask_for(qi, ki):
        qp = qi * cq + q_pos[:, None]
        kp = ki * ck + k_pos[None, :]
        m = jnp.ones((cq, ck), bool)
        if causal:
            m &= qp >= kp
        if window > 0:
            m &= (qp - kp) < window
        return m

    def q_block(qi):
        qc = qb[:, qi]
        if swa_fast:
            # Diagonal + previous block only.
            prev = jnp.maximum(qi - 1, 0)
            acc, m, l = _block_attn(qc, kb[:, qi], vb[:, qi],
                                    mask_for(qi, qi), scale)
            pmask = mask_for(qi, prev) & (qi > 0)
            a2, m2, l2 = _block_attn(qc, kb[:, prev], vb[:, prev],
                                     pmask, scale)
            acc, m, l = _combine(acc, m, l, a2, m2, l2)
        else:
            def kv_step(carry, ki):
                acc, m, l = carry
                a2, m2, l2 = _block_attn(qc, kb[:, ki], vb[:, ki],
                                         mask_for(qi, ki), scale)
                return _combine(acc, m, l, a2, m2, l2), None

            init = (jnp.zeros((B, Hk, g, cq, Dv), jnp.float32),
                    jnp.full((B, Hk, g, cq), NEG_INF, jnp.float32),
                    jnp.zeros((B, Hk, g, cq), jnp.float32))
            (acc, m, l), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4).reshape(B, cq, H, Dv)

    if nq == 1:
        out = q_block(0)
    else:
        out = jax.lax.map(q_block, jnp.arange(nq))      # (nq,B,cq,H,Dv)
        out = out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, Dv)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode (one new token against a sequence-sharded cache).
# ---------------------------------------------------------------------------

def decode_attention(q: jnp.ndarray, cache: KVCache, pos: jnp.ndarray, *,
                     window: int = 0,
                     scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B,1,H,D); cache.k/v: (B,Smax,Hk,D) with the S dim sharded over
    the ``model`` axis.  Softmax over the sharded dim lowers to the
    flash-decode psum pattern under GSPMD."""
    B, _, H, D = q.shape
    _, Smax, Hk, Dv = cache.v.shape
    g = H // Hk
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Hk, g, D)
    # Same-dtype einsums here: an f32 preferred_element_type makes
    # XLA-CPU materialize f32 CONVERTS of the cache operands, which the
    # scheduler hoists into a full f32 copy of the multi-GiB carried
    # cache.  Scores are softmaxed in f32 regardless.
    s = jnp.einsum("bhgd,bshd->bhgs", qg,
                   cache.k.astype(qg.dtype)) * scale
    s = s.astype(jnp.float32)
    valid = (cache.positions <= pos[:, None]) & (cache.positions >= 0)
    if window > 0:
        valid &= (pos[:, None] - cache.positions) < window
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(cache.v.dtype), cache.v)
    return out.reshape(B, 1, H * Dv).astype(q.dtype)


def scatter_time(buf: jnp.ndarray, new: jnp.ndarray,
                 slot: jnp.ndarray) -> jnp.ndarray:
    """Write ``new`` (B,1,...) into time slot ``slot`` of ``buf``
    (B,S,...) via a one-hot select.  Unlike a traced-index
    dynamic-update-slice, this is ELEMENTWISE over the time dim, so a
    sequence-sharded cache updates locally — no GSPMD gather/reshard of
    the multi-GiB cache per layer."""
    S = buf.shape[1]
    hit = (jnp.arange(S) == slot).reshape((1, S) + (1,) * (buf.ndim - 2))
    return jnp.where(hit, new.astype(buf.dtype), buf)


def update_cache(cache: KVCache, k_new: jnp.ndarray, v_new: jnp.ndarray,
                 pos: jnp.ndarray, *, window: int = 0) -> KVCache:
    """Insert one token (B,1,Hk,D) at ``pos`` (rolling slot if SWA)."""
    Smax = cache.k.shape[1]
    slot = (pos[0] % Smax) if window > 0 else jnp.minimum(pos[0], Smax - 1)
    k = scatter_time(cache.k, k_new, slot)
    v = scatter_time(cache.v, v_new, slot)
    positions = scatter_time(cache.positions[..., None],
                             pos[:, None, None], slot)[..., 0]
    return KVCache(k=k, v=v, positions=positions)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> KVCache:
    s = min(max_len, cfg.attn_window) if cfg.attn_window else max_len
    hk, d = cfg.n_kv_heads, cfg.head_dim
    return KVCache(
        k=jnp.zeros((batch, s, hk, d), dtype),
        v=jnp.zeros((batch, s, hk, d), dtype),
        positions=jnp.full((batch, s), -1, jnp.int32),
    )


# ---------------------------------------------------------------------------
# Full attention block (projections + rope + mixer).
# ---------------------------------------------------------------------------

def attention_apply(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                    positions: jnp.ndarray,
                    cache: Optional[KVCache] = None,
                    decode_pos: Optional[jnp.ndarray] = None):
    """Returns (out, new_cache).  ``cache`` set => write path; with
    ``decode_pos`` also set => single-token decode."""
    B, S, _ = x.shape
    h, hk, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    # Constrain the FLAT projections (always divisible by the TP axis);
    # forcing hk (often < TP size) onto the model axis triggers
    # involuntary full rematerialization in the SPMD partitioner.
    ba, ta = cfg.batch_axes, cfg.tp_axes
    q = constrain(q, ba, None, ta).reshape(B, S, h, d)
    k = constrain(k, ba, None, ta).reshape(B, S, hk, d)
    v = constrain(v, ba, None, ta).reshape(B, S, hk, d)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = cache
    if cache is not None and decode_pos is not None:
        new_cache = update_cache(cache, k, v, decode_pos,
                                 window=cfg.attn_window)
        out = decode_attention(q, new_cache, decode_pos,
                               window=cfg.attn_window)
    else:
        if cache is not None:  # prefill: persist k/v into the cache
            Smax = cache.k.shape[1]
            span = min(S, Smax)
            # Rolling (windowed) caches address slot = position % Smax;
            # align the fill so decode overwrites the OLDEST slot next.
            first_pos = (S - span) % Smax if cfg.attn_window else 0

            def fill(buf, val):  # static-shape write (no traced DUS)
                val = val[:, -span:].astype(buf.dtype)
                if span < Smax:
                    pad = [(0, 0), (0, Smax - span)] \
                        + [(0, 0)] * (val.ndim - 2)
                    val = jnp.pad(val, pad)
                return jnp.roll(val, first_pos, axis=1) if first_pos \
                    else val

            pos_grid = jnp.broadcast_to(positions[..., -span:], (B, span)
                                        ).astype(jnp.int32)
            if span < Smax:
                pos_grid = jnp.pad(pos_grid, [(0, 0), (0, Smax - span)],
                                   constant_values=-1)
            if first_pos:
                pos_grid = jnp.roll(pos_grid, first_pos, axis=1)
            new_cache = KVCache(k=fill(cache.k, k), v=fill(cache.v, v),
                                positions=pos_grid)
        out = flash_attention(q, k, v, causal=cfg.causal,
                              window=cfg.attn_window, chunk=cfg.attn_chunk)
        out = out.reshape(B, S, h * d)
    out = constrain(out, ba, None, ta)
    return out @ p["wo"].astype(dt), new_cache
