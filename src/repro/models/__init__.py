"""Model definitions: configs, layers and family stacks."""
from . import attention, layers, mla, moe, ssm, transformer
from .config import (SHAPES, SHAPES_BY_NAME, ModelConfig, ShapeCell,
                     applicable_shapes, skip_reason)
from .transformer import (forward, init_caches, init_params, loss_fn,
                          param_defs)

__all__ = [
    "SHAPES", "SHAPES_BY_NAME", "ModelConfig", "ShapeCell",
    "applicable_shapes", "attention", "forward", "init_caches",
    "init_params", "layers", "loss_fn", "mla", "moe", "param_defs",
    "skip_reason", "ssm", "transformer",
]
