"""Jitted public wrappers for every Pallas kernel.

Handles shape normalization (1-D -> TPU-aligned 2-D views, padding),
stage chaining (FFT, k-ary dot-product reduction tree) and twiddle/
basis precomputation.  Each wrapper's contract is its ref.py oracle.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from . import axpy as _axpy
from . import conv2d as _conv2d
from . import dct as _dct
from . import dotp as _dotp
from . import fft4 as _fft4
from . import flash_attn as _fa
from . import matmul as _mm
from . import ref

_LANES = 128


def _as_2d(x: jnp.ndarray):
    """Pad a 1-D array to a (rows, 128) TPU-aligned view."""
    n = x.shape[0]
    rows = -(-n // _LANES)
    pad = rows * _LANES - n
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    return x.reshape(rows, _LANES), n


@jax.jit
def axpy(a: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    x2, n = _as_2d(x)
    y2, _ = _as_2d(y)
    out = _axpy.axpy(jnp.asarray(a, x.dtype), x2, y2)
    return out.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("radix",))
def dotp(x: jnp.ndarray, y: jnp.ndarray, *, radix: int = 0) -> jnp.ndarray:
    """radix=0 -> central accumulator; k>0 -> k-ary reduction tree
    (one pallas stage per level), the paper's barrier-radix knob."""
    x2, _ = _as_2d(x)
    y2, _ = _as_2d(y)
    if radix <= 1:
        return _dotp.dotp_central(x2, y2)
    parts = _dotp.dotp_partials(x2, y2)
    while parts.shape[0] > 1:
        parts = _dotp.combine_partials(parts, radix)
    return parts[0, 0]


@jax.jit
def matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return _mm_padded(x, w)


def _mm_padded(x, w):
    m, k = x.shape
    _, n = w.shape

    def up(v, b):
        return -(-v // b) * b

    mp, kp, np_ = up(m, 8), up(k, _LANES), up(n, _LANES)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    return _mm.matmul(xp, wp)[:m, :n]


@jax.jit
def conv2d(img: jnp.ndarray, kernel: jnp.ndarray) -> jnp.ndarray:
    pad = jnp.pad(img, ((0, 0), (1, 1), (1, 1)))
    return _conv2d.conv2d(pad, kernel.astype(jnp.float32),
                          img.shape[1:])


@jax.jit
def dct(x: jnp.ndarray) -> jnp.ndarray:
    basis_t = ref.dct_basis(x.shape[-1]).T
    return _dct.dct(x, basis_t)


def _stage_twiddles(n: int, stage: int):
    m = n // (4 ** stage)
    q = m // 4
    k = jnp.arange(q, dtype=jnp.float32)
    ang = -2.0 * jnp.pi * k / m
    ws = [jnp.exp(1j * ang * j) for j in (1, 2, 3)]
    wr = jnp.stack([jnp.real(w) for w in ws]).astype(jnp.float32)
    wi = jnp.stack([jnp.imag(w) for w in ws]).astype(jnp.float32)
    return wr, wi


@jax.jit
def fft4(re: jnp.ndarray, im: jnp.ndarray):
    """Radix-4 DIF FFT over rows; returns digit-reversed spectrum
    (re, im).  Stage-by-stage pallas calls mirror the paper's
    partially-synchronized FFT schedule (Fig. 3)."""
    n = re.shape[-1]
    stages = int(round(math.log(n, 4)))
    assert 4 ** stages == n, "fft4 needs power-of-4 length"
    re = re.astype(jnp.float32)
    im = im.astype(jnp.float32)
    for s in range(stages):
        wr, wi = _stage_twiddles(n, s)
        re, im = _fft4.fft4_stage(re, im, wr, wi)
    return re, im


@functools.partial(jax.jit, static_argnames=("causal",))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True) -> jnp.ndarray:
    """q,k,v: (B,H,S,D)."""
    b, h, s, d = q.shape
    fold = lambda t: t.reshape(b * h, s, d)
    out = _fa.flash_attention(fold(q), fold(k), fold(v), causal=causal)
    return out.reshape(b, h, s, d)
