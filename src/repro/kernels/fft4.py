"""Radix-4 DIF FFT butterfly stage (the paper's 5G OFDM kernel).

TeraPool adaptation of Fig. 3: the paper schedules each butterfly
stage across 256 PEs and partially synchronizes between stages.  On
TPU one *stage* is one pallas_call (grid = independent FFT rows — the
"partial sync" boundary is the grid/pallas_call boundary, enforced by
dataflow rather than a barrier); ops.fft4 chains the log4(N) stages.
Complex math is carried as separate re/im planes (TPU has no complex
VREGs); twiddles are precomputed per stage by ops.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 8


def _stage_kernel(re_ref, im_ref, wr_ref, wi_ref, or_ref, oi_ref):
    rows, n = re_ref.shape
    q = wr_ref.shape[1]               # quarter length of sub-transform
    re = re_ref[...].reshape(rows, -1, 4, q)
    im = im_ref[...].reshape(rows, -1, 4, q)
    ar, ai = re[:, :, 0], im[:, :, 0]
    br, bi = re[:, :, 1], im[:, :, 1]
    cr, ci = re[:, :, 2], im[:, :, 2]
    dr, di = re[:, :, 3], im[:, :, 3]
    t0r, t0i = ar + cr, ai + ci
    t1r, t1i = ar - cr, ai - ci
    t2r, t2i = br + dr, bi + di
    t3r, t3i = bi - di, -(br - dr)    # -j*(b-d)
    w1r, w1i = wr_ref[0], wi_ref[0]
    w2r, w2i = wr_ref[1], wi_ref[1]
    w3r, w3i = wr_ref[2], wi_ref[2]

    def cmul(xr, xi, yr, yi):
        return xr * yr - xi * yi, xr * yi + xi * yr

    y0r, y0i = t0r + t2r, t0i + t2i
    y1r, y1i = cmul(t1r + t3r, t1i + t3i, w1r, w1i)
    y2r, y2i = cmul(t0r - t2r, t0i - t2i, w2r, w2i)
    y3r, y3i = cmul(t1r - t3r, t1i - t3i, w3r, w3i)
    or_ref[...] = jnp.stack([y0r, y1r, y2r, y3r], axis=2
                            ).reshape(rows, n)
    oi_ref[...] = jnp.stack([y0i, y1i, y2i, y3i], axis=2
                            ).reshape(rows, n)


def fft4_stage(re: jnp.ndarray, im: jnp.ndarray, wr: jnp.ndarray,
               wi: jnp.ndarray) -> tuple:
    """One DIF stage.  re/im: (rows, n); wr/wi: (3, q) twiddles for
    W^k, W^2k, W^3k with q = current sub-transform length / 4."""
    rows, n = re.shape
    bt = min(ROW_TILE, rows)
    q = wr.shape[1]
    out_shape = [jax.ShapeDtypeStruct((rows, n), jnp.float32)] * 2
    return pl.pallas_call(
        _stage_kernel,
        grid=(pl.cdiv(rows, bt),),
        in_specs=[
            pl.BlockSpec((bt, n), lambda i: (i, 0)),
            pl.BlockSpec((bt, n), lambda i: (i, 0)),
            pl.BlockSpec((3, q), lambda i: (0, 0)),
            pl.BlockSpec((3, q), lambda i: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((bt, n), lambda i: (i, 0))] * 2,
        out_shape=out_shape,
        interpret=jax.default_backend() != "tpu",
    )(re, im, wr, wi)
