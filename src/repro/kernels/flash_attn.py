"""Flash attention forward kernel (the LM stack's compute hot-spot).

Grid (B*H, n_q, n_kv); the kv axis is innermost so the running-softmax
state lives in VMEM scratch across kv steps (TPU grid steps are
sequential per core).  Causal masking from absolute block indices; the
output block is written once on the last kv step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ, BK = 512, 512
NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale: float, causal: bool, n_kv: int):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _step():
        q = q_ref[0]                               # (bq, d)
        k = k_ref[0]                               # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            bq, bk = s.shape
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32,
                                                      (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32,
                                                      (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(jnp.isfinite(m_new)[:, None], p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        alpha = jnp.where(jnp.isfinite(m_prev), alpha, 0.0)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jnp.dot(p.astype(v_ref.dtype), v_ref[0],
                                  preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    if causal:
        pl.when(ki <= qi)(_step)   # skip fully-masked blocks
    else:
        _step()

    @pl.when(ki == n_kv - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, bq: int = BQ,
                    bk: int = BK) -> jnp.ndarray:
    """q,k,v: (BH, S, D) -> (BH, S, D)."""
    bh, s, d = q.shape
    bq, bk = min(bq, s), min(bk, s)
    n_q, n_kv = pl.cdiv(s, bq), pl.cdiv(s, bk)
    scale = d ** -0.5
    return pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, causal=causal,
                          n_kv=n_kv),
        grid=(bh, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=jax.default_backend() != "tpu",
    )(q, k, v)
