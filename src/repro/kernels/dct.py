"""Row-wise DCT-II kernel (the paper's DCT benchmark).

TeraPool adaptation: the paper's bank-local 2x2 DCT blocks become a
(rows x n) @ (n x n) basis matmul on the MXU — tiles of rows stream
through VMEM against a resident basis tile.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 256


def _dct_kernel(x_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...].astype(jnp.float32), b_ref[...],
                         preferred_element_type=jnp.float32)


def dct(x: jnp.ndarray, basis_t: jnp.ndarray) -> jnp.ndarray:
    """x: (T, n); basis_t: (n, n) transposed DCT basis."""
    t, n = x.shape
    bt = min(ROW_TILE, t)
    return pl.pallas_call(
        _dct_kernel,
        grid=(pl.cdiv(t, bt),),
        in_specs=[
            pl.BlockSpec((bt, n), lambda i: (i, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        interpret=jax.default_backend() != "tpu",
    )(x, basis_t)
