"""Pallas TPU kernels for the paper's compute hot-spots.

Each ``<name>.py`` holds the ``pl.pallas_call`` + BlockSpec tiling;
``ops.py`` the jit'd public wrappers; ``ref.py`` the pure-jnp oracles.
Validated in interpret mode on CPU (tests/test_kernels.py), targeted
at TPU (MXU-aligned tiles, VMEM scratch accumulation).
"""
from . import ops, ref

__all__ = ["ops", "ref"]
