"""AXPY kernel: y <- a*x + y.

TeraPool adaptation: the paper's PE-local bank access pattern becomes
VREG-resident elementwise math on (8,128)-aligned VMEM tiles; the
"equal split across PEs" becomes the grid partition.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_ROWS = 256      # (256, 128) f32 tile = 128 KiB VMEM per operand
TILE_COLS = 128


def _axpy_kernel(a_ref, x_ref, y_ref, o_ref):
    o_ref[...] = a_ref[0, 0] * x_ref[...] + y_ref[...]


def axpy(a: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """x, y: (R, C) 2-D views (ops.py reshapes 1-D inputs)."""
    rows, cols = x.shape
    br = min(TILE_ROWS, rows)
    bc = min(TILE_COLS, cols)
    grid = (pl.cdiv(rows, br), pl.cdiv(cols, bc))
    return pl.pallas_call(
        _axpy_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=jax.default_backend() != "tpu",
    )(a.reshape(1, 1), x, y)
