"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These mirror the paper's benchmark kernels (Sec. 4.2) plus the LM
stack's attention hot-spot.  Each oracle is the mathematical truth the
tiled TPU kernels in this package are tested against (tests/
test_kernels.py sweeps shapes and dtypes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def axpy(a: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return a * x + y


def dotp(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))


def matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def conv2d(img: jnp.ndarray, kernel: jnp.ndarray) -> jnp.ndarray:
    """3x3 'same' convolution with zero padding; img (B,H,W)."""
    pad = jnp.pad(img, ((0, 0), (1, 1), (1, 1)))
    H, W = img.shape[1:]
    out = jnp.zeros_like(img, dtype=jnp.float32)
    for di in range(3):
        for dj in range(3):
            out = out + kernel[di, dj] * pad[:, di:di + H, dj:dj + W
                                             ].astype(jnp.float32)
    return out


def dct_basis(n: int) -> jnp.ndarray:
    """Orthonormal DCT-II basis (n x n)."""
    k = jnp.arange(n)[:, None].astype(jnp.float32)
    i = jnp.arange(n)[None, :].astype(jnp.float32)
    basis = jnp.cos(jnp.pi * (2 * i + 1) * k / (2 * n))
    scale = jnp.where(k == 0, jnp.sqrt(1.0 / n), jnp.sqrt(2.0 / n))
    return basis * scale


def dct(x: jnp.ndarray) -> jnp.ndarray:
    """Row-wise DCT-II; x (T, n)."""
    return x.astype(jnp.float32) @ dct_basis(x.shape[-1]).T


def _fft4_stage(re, im, stage: int, n: int):
    """One radix-4 DIF butterfly stage over rows of length n."""
    q = n // (4 ** (stage + 1))
    m = n // (4 ** stage)          # current sub-transform length
    x = (re + 1j * im).reshape(re.shape[0], -1, 4, q)  # (rows, n/m, 4, q)
    a, b, c, d = x[:, :, 0], x[:, :, 1], x[:, :, 2], x[:, :, 3]
    t0, t1 = a + c, a - c
    t2, t3 = b + d, -1j * (b - d)
    k = jnp.arange(q, dtype=jnp.float32)
    w1 = jnp.exp(-2j * jnp.pi * k / m)
    y0 = t0 + t2
    y1 = (t1 + t3) * w1
    y2 = (t0 - t2) * w1 ** 2
    y3 = (t1 - t3) * w1 ** 3
    y = jnp.stack([y0, y1, y2, y3], axis=2).reshape(re.shape)
    return jnp.real(y), jnp.imag(y)


def fft4(re: jnp.ndarray, im: jnp.ndarray):
    """Full radix-4 DIF FFT (digit-reversed output order);
    re/im (rows, n) with n a power of 4."""
    n = re.shape[-1]
    stages = 0
    m = n
    while m > 1:
        m //= 4
        stages += 1
    for s in range(stages):
        re, im = _fft4_stage(re, im, s, n)
    return re, im


def digit_reverse_indices(n: int) -> jnp.ndarray:
    """Base-4 digit reversal permutation for comparing fft4 against
    jnp.fft.fft."""
    import numpy as np
    digits = 0
    m = n
    while m > 1:
        m //= 4
        digits += 1
    idx = np.arange(n)
    out = np.zeros(n, dtype=np.int64)
    for _ in range(digits):
        out = out * 4 + idx % 4
        idx //= 4
    return jnp.asarray(out)


def flash_attention(q, k, v, *, causal: bool = True):
    """O(S^2) reference attention; q,k,v (B,H,S,D)."""
    S = q.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32)
    s = s * (q.shape[-1] ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32)
