"""3x3 same-conv kernel (the paper's Conv2D benchmark).

TeraPool adaptation: the paper's border-vs-inner work imbalance
disappears on TPU — the zero-padded halo is materialized by ops.py and
every grid step does identical shift-and-MAC work on a full image tile
(uniform arrival; the barrier-selection lesson moves to the collective
layer instead).  One grid step per image; 9 static shifted slices keep
everything in VREGs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(img_ref, k_ref, o_ref):
    h, w = o_ref.shape[1], o_ref.shape[2]
    acc = jnp.zeros((1, h, w), jnp.float32)
    for di in range(3):
        for dj in range(3):
            acc += k_ref[di, dj] * img_ref[:, di:di + h, dj:dj + w
                                           ].astype(jnp.float32)
    o_ref[...] = acc


def conv2d(img_padded: jnp.ndarray, kernel: jnp.ndarray,
           out_hw: tuple) -> jnp.ndarray:
    """img_padded: (B, H+2, W+2) zero-padded; kernel: (3,3)."""
    b = img_padded.shape[0]
    h, w = out_hw
    return pl.pallas_call(
        _conv_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h + 2, w + 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((3, 3), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, w), jnp.float32),
        interpret=jax.default_backend() != "tpu",
    )(img_padded, kernel)
