"""Dot-product with a k-ary reduction tree — the paper's DOTP kernel.

TeraPool adaptation of the *barrier-coupled reduction*: the paper's
PEs atomically add partial sums to ONE shared variable (serialized by
the bank — the central-counter pattern).  On TPU the analogue of the
shared variable is a revisited output block: every grid step
accumulates its partial sum into the same (1,1) output tile (TPU grid
steps execute sequentially per core, so the accumulation is exactly the
serialized atomic).  The *k-ary tree* variant (ops.radix_dotp) splits
the reduction into a partial-sums stage and a combine stage, one pallas
call per tree level.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_ROWS = 256
TILE_COLS = 128


def _dotp_kernel(x_ref, y_ref, o_ref):
    part = jnp.sum(x_ref[...].astype(jnp.float32)
                   * y_ref[...].astype(jnp.float32))

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[0, 0] = jnp.zeros((), jnp.float32)

    o_ref[0, 0] += part


def dotp_central(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Central-counter analogue: one revisited accumulator tile."""
    rows, cols = x.shape
    br, bc = min(TILE_ROWS, rows), min(TILE_COLS, cols)
    grid = (pl.cdiv(rows, br) * pl.cdiv(cols, bc),)
    nc = pl.cdiv(cols, bc)
    return pl.pallas_call(
        _dotp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda g: (g // nc, g % nc)),
            pl.BlockSpec((br, bc), lambda g: (g // nc, g % nc)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda g: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=jax.default_backend() != "tpu",
    )(x, y)[0, 0]


def _partial_kernel(x_ref, y_ref, o_ref):
    o_ref[0, 0] = jnp.sum(x_ref[...].astype(jnp.float32)
                          * y_ref[...].astype(jnp.float32))


def dotp_partials(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Leaf level of the tree: one independent partial sum per block
    (no shared accumulator -> no serialization)."""
    rows, cols = x.shape
    br, bc = min(TILE_ROWS, rows), min(TILE_COLS, cols)
    nr, nc = pl.cdiv(rows, br), pl.cdiv(cols, bc)
    return pl.pallas_call(
        _partial_kernel,
        grid=(nr * nc,),
        in_specs=[
            pl.BlockSpec((br, bc), lambda g: (g // nc, g % nc)),
            pl.BlockSpec((br, bc), lambda g: (g // nc, g % nc)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((nr * nc, 1), jnp.float32),
        interpret=jax.default_backend() != "tpu",
    )(x, y)


def _combine_kernel(p_ref, o_ref):
    o_ref[0, 0] = jnp.sum(p_ref[...])


def combine_partials(parts: jnp.ndarray, radix: int) -> jnp.ndarray:
    """One k-ary tree level: groups of ``radix`` partials -> 1."""
    n = parts.shape[0]
    pad = (-n) % radix
    if pad:
        parts = jnp.concatenate(
            [parts, jnp.zeros((pad, 1), parts.dtype)], axis=0)
    groups = parts.shape[0] // radix
    return pl.pallas_call(
        _combine_kernel,
        grid=(groups,),
        in_specs=[pl.BlockSpec((radix, 1), lambda g: (g, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((groups, 1), jnp.float32),
        interpret=jax.default_backend() != "tpu",
    )(parts)
