"""Tiled MXU matmul (the paper's MATMUL / beamforming kernel).

TeraPool adaptation: the column-wise PE distribution becomes an
(M/bm x N/bn) output grid; the row-broadcast over the shared
interconnect becomes the k-loop streaming (bm,bk)/(bk,bn) tiles
HBM -> VMEM with f32 accumulation in a VMEM scratch (MXU-aligned
128-multiples).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BM, BN, BK = 256, 256, 512


def _mm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(x: jnp.ndarray, w: jnp.ndarray, *,
           bm: int = BM, bn: int = BN, bk: int = BK) -> jnp.ndarray:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    return pl.pallas_call(
        functools.partial(_mm_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=jax.default_backend() != "tpu",
    )(x, w)
