"""Sharded, atomic checkpointing.

Layout: one directory per step, one ``.npz`` per host holding that
host's addressable parameter/optimizer shards, plus a JSON manifest.
Writes are crash-safe: everything lands in ``<dir>.tmp`` and a single
atomic rename publishes the step; ``latest_step`` only believes
directories whose manifest is complete.  The fault-tolerant runtime
(runtime/fault.py) restarts from ``restore`` after any failure.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "name", None))
                           or getattr(p, "idx", p)) for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str | Path, step: int, tree: Any, *,
         host_id: int = 0, host_count: int = 1,
         extra: Optional[Dict[str, Any]] = None) -> Path:
    """Atomically save ``tree`` for this host.  Multi-host: every host
    calls save; host 0 publishes the rename once all host files exist."""
    root = Path(ckpt_dir)
    final = root / f"step_{step:08d}"
    tmp = root / f"step_{step:08d}.tmp"
    tmp.mkdir(parents=True, exist_ok=True)

    flat = _flatten(tree)

    def to_np(v):
        a = np.asarray(v)
        if a.dtype.kind == "V":   # bfloat16 etc: npz can't round-trip
            a = np.asarray(jnp.asarray(v).astype(jnp.float32))
        return a

    arrays = {k: to_np(v) for k, v in flat.items()}
    np.savez(tmp / f"host_{host_id:04d}.npz", **arrays)

    if host_id == 0:
        manifest = {"step": step, "host_count": host_count,
                    "keys": sorted(arrays.keys()),
                    "time": time.time(), "extra": extra or {}}
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))

    # Publish when every host file is present (single-process test runs
    # reach this immediately).
    ready = all((tmp / f"host_{h:04d}.npz").exists()
                for h in range(host_count))
    if ready and (tmp / "manifest.json").exists():
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        return final
    return tmp


def _manifest_ok(step_dir: Path) -> bool:
    """A checkpoint directory counts only if its manifest parses and
    names a step — a crash between file creation and write (or a
    torn/truncated write on a non-atomic filesystem) must make the
    directory invisible to resume, not crash it."""
    try:
        manifest = json.loads((step_dir / "manifest.json").read_text())
    except (OSError, json.JSONDecodeError, UnicodeDecodeError):
        return False
    return isinstance(manifest, dict) and "step" in manifest


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    root = Path(ckpt_dir)
    if not root.exists():
        return None
    steps = []
    for d in root.iterdir():
        if d.is_dir() and d.name.startswith("step_") \
                and not d.name.endswith(".tmp") \
                and _manifest_ok(d):
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, template: Any, *,
            step: Optional[int] = None, host_id: int = 0
            ) -> Tuple[Any, Dict[str, Any]]:
    """Restore this host's shards into the structure of ``template``."""
    root = Path(ckpt_dir)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = root / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / f"host_{host_id:04d}.npz")

    flat_t = _flatten(template)
    missing = set(flat_t) - set(data.files)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}")
    leaves_order = list(flat_t.keys())
    restored = [jnp.asarray(data[k]).astype(flat_t[k].dtype)
                if hasattr(flat_t[k], "dtype") else data[k]
                for k in leaves_order]
    treedef = jax.tree.structure(template)
    return jax.tree.unflatten(treedef, restored), manifest


def prune(ckpt_dir: str | Path, keep: int = 3) -> None:
    """Keep the newest ``keep`` complete checkpoints (and drop stale
    .tmp dirs older than an hour)."""
    root = Path(ckpt_dir)
    if not root.exists():
        return
    done = sorted(d for d in root.iterdir()
                  if d.is_dir() and d.name.startswith("step_")
                  and not d.name.endswith(".tmp"))
    for d in done[:-keep] if keep else done:
        shutil.rmtree(d)
    cutoff = time.time() - 3600
    for d in root.glob("*.tmp"):
        if d.stat().st_mtime < cutoff:
            shutil.rmtree(d)
