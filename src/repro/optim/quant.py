"""Channel-wise int8 quantization for optimizer moments.

A distributed-optimization memory trick for the >=100B configs: Adam
moments are stored as int8 with one fp32 scale per *channel* (all but
the last dim), cutting optimizer-state HBM from 4 to ~1 byte/param.

Channel-wise (rather than flat-block) scales are deliberate: the scale
tensor is exactly the parameter's shape minus its last dim, so it
inherits the parameter's leading-dim sharding verbatim, and a
parameter sharded on its *last* dim broadcasts against a replicated
scale — no sharding-divisibility corner cases anywhere.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp


class QTensor(NamedTuple):
    q: jnp.ndarray       # int8, original shape
    scale: jnp.ndarray   # f32, shape[:-1]


def quantize(x: jnp.ndarray) -> QTensor:
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1) if x.ndim else jnp.abs(xf)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale[..., None] if x.ndim
                           else xf / scale), -127, 127)
    return QTensor(q=q.astype(jnp.int8), scale=scale)


def dequantize(t: QTensor) -> jnp.ndarray:
    qf = t.q.astype(jnp.float32)
    return qf * (t.scale[..., None] if t.q.ndim else t.scale)


def factored_dims(shape: Tuple[int, ...]):
    """Adafactor-style factoring: the two trailing dims of a >=2D
    tensor (None for scalars/vectors)."""
    if len(shape) < 2:
        return None
    return len(shape) - 2, len(shape) - 1
