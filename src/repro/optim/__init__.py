"""Optimizers with distributed-state layouts."""
from . import quant
from .adamw import (OptConfig, global_norm_sq, init, schedule, state_defs,
                    update)

__all__ = ["OptConfig", "global_norm_sq", "init", "quant", "schedule",
           "state_defs", "update"]
