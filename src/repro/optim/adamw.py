"""AdamW with distributed-state layout knobs.

Runs entirely on local parameter *shards* inside the manual shard_map
region (ZeRO-1/2/3 style): every update is elementwise, so no
collectives are needed beyond the global-grad-norm psum that the step
function supplies.

Memory knobs per ModelConfig:
  * ``master_dtype``  — fp32 master copies, or bf16 (update in fp32
    math, store bf16; >=300B configs).
  * ``moment_dtype``  — fp32 | bf16 | int8 (block-quantized, quant.py).
  * ``factored_second_moment`` — Adafactor-style rank-1 v for >=2D
    tensors (DeepSeek-671B plan).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import quant
from ..models.layers import ParamDef


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    master_dtype: str = "float32"
    moment_dtype: str = "float32"
    factored_second_moment: bool = False

    @staticmethod
    def from_model(mcfg, **overrides) -> "OptConfig":
        base = dict(master_dtype=mcfg.master_dtype,
                    moment_dtype=mcfg.moment_dtype,
                    factored_second_moment=mcfg.factored_second_moment)
        base.update(overrides)
        return OptConfig(**base)


def schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to 10%."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                 0.0, 1.0)
    cos = 0.1 + 0.45 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


# --------------------------------------------------------------------------
# Moment storage.
# --------------------------------------------------------------------------

def _store_moment(x: jnp.ndarray, dtype: str):
    if dtype == "int8":
        return quant.quantize(x)
    return x.astype(jnp.dtype(dtype))


def _load_moment(s, dtype: str) -> jnp.ndarray:
    if dtype == "int8":
        return quant.dequantize(s)
    return s.astype(jnp.float32)


def _init_moment(shape, dtype: str):
    return _store_moment(jnp.zeros(shape, jnp.float32), dtype)


def _init_v(shape, cfg: OptConfig):
    dims = quant.factored_dims(shape) if cfg.factored_second_moment else None
    if dims is None:
        return {"full": _init_moment(shape, cfg.moment_dtype)}
    r, c = dims
    row_shape = shape[:r] + (shape[r],)
    col_shape = shape[:r] + (shape[c],)
    return {"row": jnp.zeros(row_shape, jnp.float32),
            "col": jnp.zeros(col_shape, jnp.float32)}


def _v_update_and_read(v_state, g2: jnp.ndarray, b2: float,
                       cfg: OptConfig):
    """Returns (new_state, dense v estimate)."""
    if "full" in v_state:
        v = _load_moment(v_state["full"], cfg.moment_dtype)
        v = b2 * v + (1 - b2) * g2
        return {"full": _store_moment(v, cfg.moment_dtype)}, v
    row = b2 * v_state["row"] + (1 - b2) * jnp.mean(g2, axis=-1)
    col = b2 * v_state["col"] + (1 - b2) * jnp.mean(g2, axis=-2)
    mean_row = jnp.mean(row, axis=-1, keepdims=True)
    v = (row[..., :, None] * col[..., None, :]
         / jnp.maximum(mean_row[..., None], 1e-30))
    return {"row": row, "col": col}, v


# --------------------------------------------------------------------------
# Public API.
# --------------------------------------------------------------------------

def init(params, cfg: OptConfig) -> Dict[str, Any]:
    def leaf(p):
        state = {"m": _init_moment(p.shape, cfg.moment_dtype),
                 "v": _init_v(p.shape, cfg)}
        if cfg.master_dtype == "float32" and p.dtype != jnp.float32:
            state["master"] = p.astype(jnp.float32)
        return state

    return {"step": jnp.zeros((), jnp.int32),
            "params": jax.tree.map(leaf, params)}


def _drop_dim(d: ParamDef, dim: int, dtype: str) -> ParamDef:
    """ParamDef for a tensor that removes one dim of ``d`` (scales,
    factored moments): shardings shift accordingly."""
    fsdp = d.fsdp_dim
    if fsdp is not None:
        fsdp = None if fsdp == dim else (fsdp - 1 if fsdp > dim else fsdp)
    return ParamDef(shape=d.shape[:dim] + d.shape[dim + 1:],
                    tp=d.tp[:dim] + d.tp[dim + 1:],
                    fsdp_dim=fsdp, dtype=dtype, init="zeros")


def state_defs(param_def_tree, cfg: OptConfig):
    """ParamDef mirror of :func:`init`'s state tree — the single source
    the launcher uses to derive optimizer-state shardings."""

    def _moment_def(d: ParamDef):
        if cfg.moment_dtype == "int8":
            return quant.QTensor(
                q=dataclasses.replace(d, dtype="int8", init="zeros"),
                scale=_drop_dim(d, len(d.shape) - 1, "float32")
                if d.shape else dataclasses.replace(d, dtype="float32",
                                                    init="zeros"))
        return dataclasses.replace(d, dtype=cfg.moment_dtype, init="zeros")

    def leaf(d: ParamDef):
        nd = len(d.shape)
        state = {"m": _moment_def(d)}
        if cfg.factored_second_moment and nd >= 2:
            state["v"] = {"row": _drop_dim(d, nd - 1, "float32"),
                          "col": _drop_dim(d, nd - 2, "float32")}
        else:
            state["v"] = {"full": _moment_def(d)}
        if cfg.master_dtype == "float32" and d.dtype != "float32":
            state["master"] = dataclasses.replace(d, dtype="float32")
        return state

    tree = jax.tree.map(leaf, param_def_tree,
                        is_leaf=lambda x: isinstance(x, ParamDef))
    return {"step": ParamDef((), (), fsdp_dim=None, dtype="int32",
                             init="zeros"),
            "params": tree}


def global_norm_sq(grads) -> jnp.ndarray:
    return sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
               for g in jax.tree.leaves(grads))


def update(grads, state, params, cfg: OptConfig, *,
           norm_sq: Optional[jnp.ndarray] = None
           ) -> Tuple[Any, Dict[str, Any]]:
    """One AdamW step.  ``norm_sq`` is the *global* squared grad norm
    (caller psums it across manual axes); local if omitted."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    if norm_sq is None:
        norm_sq = global_norm_sq(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm
                       / jnp.maximum(jnp.sqrt(norm_sq), 1e-12))
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def leaf(g, s, p):
        g = g.astype(jnp.float32) * clip
        m = _load_moment(s["m"], cfg.moment_dtype)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        new_v_state, v = _v_update_and_read(s["v"], jnp.square(g),
                                            cfg.b2, cfg)
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        master = s.get("master", p).astype(jnp.float32)
        master = master - lr * (upd + cfg.weight_decay * master)
        new_s = {"m": _store_moment(m, cfg.moment_dtype),
                 "v": new_v_state}
        if "master" in s:
            new_s["master"] = master
        return master.astype(p.dtype), new_s

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state["params"])
    new = [leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_params = jax.tree.unflatten(treedef, [a for a, _ in new])
    new_state = {"step": step,
                 "params": jax.tree.unflatten(treedef,
                                              [b for _, b in new])}
    return new_params, new_state
