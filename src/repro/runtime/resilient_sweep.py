"""Resilient sweep serving: checkpoint/resume, fault tolerance and
elastic re-sharding for the one-compile barrier sweeps.

The paper's insight — one late PE stalls the whole barrier — applies
to the tuning service itself: a 512-composition x placement x workload
sweep sharded across devices is only as durable as its flakiest
device.  This module wraps the chunked sweep loop of
:mod:`repro.core.sweep` in the production loop the seed runtime
(:mod:`repro.runtime.fault` / :mod:`repro.runtime.elastic`) sketched
for training:

* **Per-chunk atomic checkpointing** — every completed trial chunk is
  published with :mod:`repro.checkpoint`'s tmp-dir + ``os.replace``
  pattern.  Because each chunk is a pure function of ``(key, lo, hi)``
  (the Monte-Carlo unit block is drawn once, up front, exactly as
  :func:`repro.core.sweep.sweep_schedules` draws it), a killed sweep
  resumed from its checkpoint directory returns BIT-FOR-BIT the same
  arrays as an uninterrupted run.
* **Deterministic fault injection** — a
  :class:`~repro.runtime.inject.FaultPlan` raises simulated
  device-loss / OOM / preemption faults at chosen chunk boundaries
  (CPU-testable; see :mod:`repro.runtime.inject`).
* **Supervised retry** — non-fatal faults restart the chunk loop with
  exponential, jitter-capped backoff (:func:`repro.runtime.fault.
  backoff_delay`) up to ``max_restarts``; chunks already in memory or
  on disk are never recomputed.  A per-chunk wall-time straggler
  watchdog (median-relative, like the runner's per-step watchdog)
  raises :class:`~repro.runtime.fault.StragglerAbort` so a sweep stuck
  on one slow chunk gets rescheduled instead of stalling the grid.
* **Elastic re-sharding** — on device loss the mesh is rebuilt from
  the survivors (:func:`repro.runtime.elastic.viable_schedule_devices`
  for delay grids, :func:`~repro.runtime.elastic.viable_grid_devices`
  for 2-D schedule x kernel arrival grids) and the sweep continues on
  the smaller mesh.  ``shard_map`` results are device-count-invariant
  (tests/test_telescope.py), so shrinking the mesh preserves
  bit-for-bit equality too.
* **Multi-host chunk stores** — ``host_id``/``host_count`` in
  :class:`ResilienceConfig` interleave chunk ownership across hosts
  sharing one checkpoint directory: each host computes chunks
  ``idx % host_count == host_id``, restores the rest from the store,
  and reports (by raising) exactly which foreign chunks are still
  missing so an orchestrator can re-poll until the grid assembles.

Entry points mirror the plain engines one-for-one —
:func:`resilient_sweep_schedules` / :func:`resilient_sweep_arrivals`
drive :func:`repro.core.sweep.sweep_schedules` /
:func:`~repro.core.sweep.sweep_arrivals` semantics, and
:func:`resilient_tune_barrier` / :func:`resilient_sweep_workloads`
wrap the tuner grids of :mod:`repro.core.tuning`.  Each returns a
:class:`SweepReport` carrying the ordinary result object plus the
resilience ledger (chunks resumed vs computed, restarts, faults,
mesh-width history, checkpoint time).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import statistics
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .. import checkpoint
from ..core import barrier, barrier_sim
from ..core import sweep as sweep_mod
from ..core.barrier_sim import BarrierResult
from ..core.topology import DEFAULT, TeraPoolConfig
from . import elastic
from .fault import StragglerAbort, backoff_delay
from .inject import DeviceLoss, FaultPlan, SimulatedFault

# Per-chunk trial-axis width when the caller does not choose one: small
# enough that a kill forfeits little work, large enough that the
# checkpoint write stays a rounding error next to the N=1024 grid
# compute (bench_resilience.py measures the overhead).
DEFAULT_TRIAL_CHUNK = 8


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Knobs of the resilient chunk loop."""

    ckpt_dir: str
    trial_chunk: int = DEFAULT_TRIAL_CHUNK
    max_restarts: int = 8
    backoff_base: float = 0.02
    backoff_cap: float = 1.0
    backoff_jitter: float = 0.25
    # Chunks slower than factor x the running median (and above the
    # floor — compile of the first chunk must never trip it) abort the
    # attempt so the supervisor can reschedule.
    straggler_factor: float = 50.0
    straggler_floor: float = 30.0
    min_devices: int = 1
    cleanup: bool = False     # drop the chunk store once the result is out
    # Multi-host chunk ownership: host ``host_id`` of ``host_count``
    # computes the chunks with ``idx % host_count == host_id`` and
    # restores every other chunk from the shared store (all hosts point
    # ``ckpt_dir`` at the same filesystem).  A host whose unowned
    # chunks are not on disk yet raises listing the missing indices —
    # rerun it after the owners have published (the store digest is
    # host-independent, so any host's chunks interchange bit-for-bit).
    host_id: int = 0
    host_count: int = 1

    def __post_init__(self):
        if self.host_count < 1:
            raise ValueError(f"host_count must be >= 1, got "
                             f"{self.host_count}")
        if not 0 <= self.host_id < self.host_count:
            raise ValueError(
                f"host_id {self.host_id} outside [0, {self.host_count})")


@dataclasses.dataclass
class SweepReport:
    """A sweep result plus the resilience ledger of how it was made."""

    result: object                 # SweepResult | ArrivalSweepResult
    chunks_total: int = 0
    chunks_resumed: int = 0        # restored from the checkpoint store
    chunks_computed: int = 0       # executed (and checkpointed) now
    restarts: int = 0              # in-process supervisor restarts
    faults: List[str] = dataclasses.field(default_factory=list)
    fault_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    device_history: List[int] = dataclasses.field(default_factory=list)
    wall_seconds: float = 0.0
    ckpt_seconds: float = 0.0      # time inside checkpoint save/restore
    backoff_seconds: float = 0.0   # total supervisor backoff slept


def _run_digest(parts: Sequence) -> str:
    """Stable digest of everything a chunked run's results depend on —
    a checkpoint store only resumes a run with the SAME digest."""
    h = hashlib.sha256()
    for p in parts:
        if isinstance(p, (np.ndarray, jnp.ndarray)):
            h.update(np.ascontiguousarray(np.asarray(p)).tobytes())
        else:
            h.update(repr(p).encode())
        h.update(b"\x00")
    return h.hexdigest()


class _ChunkedGrid:
    """Chunk-by-chunk executor of one (tables, fixed, block) grid with
    checkpoint/resume, fault injection, a straggler watchdog and
    elastic re-sharding.  ``chunk_fn(lo, hi)`` builds the donated
    block slice for one trial chunk; ``chunk_shape(lo, hi)`` is the
    result-array shape of that chunk (for the restore template)."""

    def __init__(self, kind: str, tables, fixed, chunk_fn, chunk_shape,
                 n_trials: int, cfg: TeraPoolConfig, core: str,
                 rcfg: ResilienceConfig, plan: Optional[FaultPlan],
                 devices: Optional[Sequence], digest: str,
                 sleep: Callable[[float], None],
                 clock: Callable[[], float],
                 n_kernels: Optional[int] = None):
        self.kind = kind
        self.n_kernels = n_kernels
        self.tables = tables
        self.fixed = fixed
        self.chunk_fn = chunk_fn
        self.chunk_shape = chunk_shape
        self.cfg = cfg
        self.core = core
        self.rcfg = rcfg
        self.plan = plan
        self.devices = (tuple(devices) if devices is not None
                        else tuple(jax.devices()))
        self.sleep = sleep
        self.clock = clock
        self.root = Path(rcfg.ckpt_dir)
        self.chunks = list(sweep_mod._trial_chunks(n_trials,
                                                   rcfg.trial_chunk))
        self.report = SweepReport(result=None,
                                  chunks_total=len(self.chunks))
        self.report.device_history.append(len(self.devices))
        self._parts: dict = {}          # chunk idx -> BarrierResult
        self._durations: List[float] = []
        self._prepare_store(digest)

    # -- checkpoint store -------------------------------------------------
    def _prepare_store(self, digest: str) -> None:
        """Bind the store to this run's digest; wipe a stale store left
        by a DIFFERENT run (never silently mix chunk sets)."""
        meta_path = self.root / "meta.json"
        if meta_path.exists():
            try:
                meta = json.loads(meta_path.read_text())
            except (OSError, json.JSONDecodeError):
                meta = {}
            if meta.get("digest") == digest:
                return
            shutil.rmtree(self.root)
        self.root.mkdir(parents=True, exist_ok=True)
        tmp = self.root / "meta.json.tmp"
        tmp.write_text(json.dumps({"digest": digest,
                                   "chunks": len(self.chunks)}, indent=1))
        os.replace(tmp, meta_path)

    def _template(self, lo: int, hi: int) -> dict:
        shape = self.chunk_shape(lo, hi)
        dtypes = {"completed": np.bool_, "abandoned_pes": np.int32,
                  "timed_out_levels": np.int32}
        return {f: np.zeros(shape, dtypes.get(f, np.float32))
                for f in BarrierResult._fields}

    def _restore_chunk(self, idx: int, lo: int, hi: int
                       ) -> Optional[BarrierResult]:
        """The chunk's checkpointed result, or ``None`` if absent or
        unreadable (unreadable == recompute, never trust)."""
        step_dir = self.root / f"step_{idx:08d}"
        if not step_dir.exists():
            return None
        t0 = self.clock()
        try:
            tree, _ = checkpoint.restore(self.root, self._template(lo, hi),
                                         step=idx)
        except Exception:           # torn/corrupt chunk: recompute it
            return None
        finally:
            self.report.ckpt_seconds += self.clock() - t0
        return BarrierResult(**{f: np.asarray(tree[f])
                                for f in BarrierResult._fields})

    def _save_chunk(self, idx: int, res: BarrierResult) -> None:
        t0 = self.clock()
        checkpoint.save(self.root, idx,
                        {f: v for f, v in zip(BarrierResult._fields, res)})
        self.report.ckpt_seconds += self.clock() - t0

    # -- watchdog ---------------------------------------------------------
    def _watch(self, seconds: float) -> None:
        if len(self._durations) >= 3:
            med = statistics.median(self._durations)
            limit = max(self.rcfg.straggler_floor,
                        self.rcfg.straggler_factor * med)
            if seconds > limit:
                raise StragglerAbort(
                    f"chunk took {seconds:.3f}s > {limit:.3f}s "
                    f"({self.rcfg.straggler_factor}x median {med:.3f}s)")
        self._durations.append(seconds)

    def _owns(self, idx: int) -> bool:
        """Chunk ownership under the interleaved multi-host split."""
        return idx % self.rcfg.host_count == self.rcfg.host_id

    # -- chunk loop -------------------------------------------------------
    def _attempt(self) -> None:
        missing: List[int] = []
        for idx, (lo, hi) in enumerate(self.chunks):
            if self.plan is not None:
                self.plan.at_chunk(idx)
            if idx in self._parts:
                continue
            restored = self._restore_chunk(idx, lo, hi)
            if restored is not None:
                self._parts[idx] = restored
                self.report.chunks_resumed += 1
                continue
            if not self._owns(idx):
                # Another host's chunk, not published yet: keep
                # computing our own share and report the gap at the
                # end so a rerun can fill it from the store.
                missing.append(idx)
                continue
            t0 = self.clock()
            res = sweep_mod._dispatch_grid(
                self.kind, self.tables, self.fixed, self.chunk_fn(lo, hi),
                self.cfg, self.core, shard=True, devices=self.devices)
            res = jax.block_until_ready(res)
            dt = self.clock() - t0
            if self.plan is not None:
                dt += self.plan.straggle_seconds(idx)
            self._watch(dt)
            # Pull the chunk to host arrays: chunks computed on
            # different-width meshes (before/after a re-shard) carry
            # incompatible shardings that jnp.concatenate rejects, and
            # device->host transfers are bit-exact.
            res = BarrierResult(*(np.asarray(f) for f in res))
            self._save_chunk(idx, res)
            self._parts[idx] = res
            self.report.chunks_computed += 1
        if missing:
            raise RuntimeError(
                f"host {self.rcfg.host_id}/{self.rcfg.host_count} "
                f"computed its own chunks but chunk(s) {missing} owned "
                f"by other host(s) are not in the store yet; rerun "
                f"after the owners publish")

    def _remesh(self, survivors: Sequence) -> Optional[tuple]:
        """The survivors' mesh, shaped the way a fresh dispatch would
        shard this grid: 2-D (schedule x kernel) capable for arrival
        grids, schedule-axis-only for delay grids."""
        n_sched = self.tables.group_sizes.shape[0]
        if self.kind == "arrival" and self.n_kernels is not None:
            return elastic.viable_grid_devices(
                survivors, n_sched, self.n_kernels,
                min_devices=self.rcfg.min_devices)
        return elastic.viable_schedule_devices(
            survivors, n_sched, min_devices=self.rcfg.min_devices)

    def _on_fault(self, exc: Exception) -> None:
        self.report.faults.append(str(exc))
        cls = type(exc).__name__
        self.report.fault_counts[cls] = (
            self.report.fault_counts.get(cls, 0) + 1)
        if self.report.restarts >= self.rcfg.max_restarts:
            raise RuntimeError(
                f"giving up after {self.rcfg.max_restarts} restarts "
                f"(faults: {self.report.faults})") from exc
        if isinstance(exc, DeviceLoss):
            survivors = self.devices[:max(0, len(self.devices)
                                          - exc.n_lost)]
            mesh = self._remesh(survivors)
            if mesh is None:
                raise RuntimeError(
                    f"only {len(survivors)} device(s) survive; need "
                    f">= {self.rcfg.min_devices}") from exc
            self.devices = mesh
            self.report.device_history.append(len(mesh))
        delay = backoff_delay(self.report.restarts,
                              base=self.rcfg.backoff_base,
                              cap=self.rcfg.backoff_cap,
                              jitter=self.rcfg.backoff_jitter)
        self.report.backoff_seconds += delay
        self.sleep(delay)
        self.report.restarts += 1
        self._durations.clear()       # fresh watchdog baseline

    def run(self) -> BarrierResult:
        t0 = self.clock()
        while True:
            try:
                self._attempt()
                break
            except SimulatedFault as e:
                if e.fatal:
                    raise               # process death: resume next call
                self._on_fault(e)
            except StragglerAbort as e:
                self._on_fault(e)
        out = sweep_mod._concat_results(
            [self._parts[i] for i in range(len(self.chunks))])
        out = BarrierResult(*(jnp.asarray(f) for f in out))
        self.report.wall_seconds = self.clock() - t0
        if self.rcfg.cleanup:
            shutil.rmtree(self.root, ignore_errors=True)
        return out


def resilient_sweep_schedules(
        key: jax.Array, schedules: Sequence[barrier.BarrierSchedule],
        delays: Sequence[float] = (0.0, 128.0, 512.0, 2048.0),
        n_trials: int = 16, cfg: TeraPoolConfig = DEFAULT,
        placements: Sequence | None = None, *,
        resilience: ResilienceConfig, core: str | None = None,
        fault_plan: Optional[FaultPlan] = None,
        devices: Optional[Sequence] = None,
        sleep: Callable[[float], None] = time.sleep,
        clock: Callable[[], float] = time.perf_counter) -> SweepReport:
    """:func:`repro.core.sweep.sweep_schedules`, chunk-by-chunk with
    checkpoint/resume.  The unit block is drawn exactly as the plain
    engine draws it and each chunk is the same ``_dispatch_grid`` call
    the plain chunked path makes, so the assembled
    :class:`~repro.core.sweep.SweepResult` is bit-for-bit identical to
    an uninterrupted (chunked or unchunked) sweep — killed, resumed,
    re-sharded or not."""
    schedules = tuple(schedules)
    tables = barrier.stack_tables(schedules, cfg, placements)
    n = schedules[0].n_pes
    unit = jax.random.uniform(key, (n_trials, n), jnp.float32, 0.0, 1.0)
    d = jnp.asarray(delays, jnp.float32)
    core = barrier_sim.resolve_core(core)
    names = sweep_mod._stack_names(
        schedules, tuple(placements) if placements is not None else ())
    digest = _run_digest(["sweep", names, unit, d, n_trials,
                          resilience.trial_chunk, cfg, core])
    s_count = len(schedules)
    driver = _ChunkedGrid(
        "sweep", tables, d,
        chunk_fn=lambda lo, hi: jnp.copy(unit[lo:hi]),
        chunk_shape=lambda lo, hi: (s_count, d.shape[0], hi - lo),
        n_trials=n_trials, cfg=cfg, core=core, rcfg=resilience,
        plan=fault_plan, devices=devices, digest=digest, sleep=sleep,
        clock=clock)
    res = driver.run()
    placements = tuple(placements) if placements is not None else ()
    driver.report.result = sweep_mod.SweepResult(
        schedules=schedules, delays=d, placements=placements,
        **res._asdict())
    return driver.report


def resilient_sweep_arrivals(
        arrivals, schedules: Sequence[barrier.BarrierSchedule],
        cfg: TeraPoolConfig = DEFAULT, placements: Sequence | None = None,
        kernels: Sequence[str] | None = None, *,
        resilience: ResilienceConfig, core: str | None = None,
        fault_plan: Optional[FaultPlan] = None,
        devices: Optional[Sequence] = None,
        sleep: Callable[[float], None] = time.sleep,
        clock: Callable[[], float] = time.perf_counter) -> SweepReport:
    """:func:`repro.core.sweep.sweep_arrivals` with the resilient chunk
    loop — same validation, same grid calls, same bit-for-bit
    guarantee as :func:`resilient_sweep_schedules`."""
    arrivals = jnp.asarray(arrivals, jnp.float32)
    if arrivals.ndim == 2:
        arrivals = arrivals[None]
    if arrivals.ndim != 3:
        raise ValueError(
            f"arrivals must be (n_kernels, n_trials, n_pes) or "
            f"(n_trials, n_pes), got shape {arrivals.shape}")
    schedules = tuple(schedules)
    if schedules and arrivals.shape[-1] != schedules[0].n_pes:
        raise ValueError(
            f"arrivals has {arrivals.shape[-1]} PEs, schedules expect "
            f"{schedules[0].n_pes}")
    if kernels is not None and len(kernels) != arrivals.shape[0]:
        raise ValueError(
            f"{arrivals.shape[0]} arrival stacks but {len(kernels)} "
            f"kernel names")
    tables = barrier.stack_tables(schedules, cfg, placements)
    core = barrier_sim.resolve_core(core)
    n_trials = arrivals.shape[1]
    fixed = jnp.zeros((0,), jnp.float32)
    names = sweep_mod._stack_names(
        schedules, tuple(placements) if placements is not None else ())
    digest = _run_digest(["arrival", names, arrivals,
                          resilience.trial_chunk, cfg, core])
    s_count, k_count = len(schedules), arrivals.shape[0]
    driver = _ChunkedGrid(
        "arrival", tables, fixed,
        chunk_fn=lambda lo, hi: jnp.copy(arrivals[:, lo:hi]),
        chunk_shape=lambda lo, hi: (s_count, k_count, hi - lo),
        n_trials=n_trials, cfg=cfg, core=core, rcfg=resilience,
        plan=fault_plan, devices=devices, digest=digest, sleep=sleep,
        clock=clock, n_kernels=k_count)
    res = driver.run()
    kernels = (tuple(kernels) if kernels is not None
               else tuple(f"workload{i}" for i in range(k_count)))
    placements = tuple(placements) if placements is not None else ()
    driver.report.result = sweep_mod.ArrivalSweepResult(
        schedules=schedules, kernels=kernels, placements=placements,
        **res._asdict())
    return driver.report


def resilient_tune_barrier(
        key, n_pes: int | None = None,
        delays: Sequence[float] = (0.0, 128.0, 512.0, 2048.0),
        n_trials: int = 16, cfg: TeraPoolConfig = DEFAULT, *,
        prune: str = "none", schedules=None,
        placements: Sequence[str] | None = None,
        resilience: ResilienceConfig, core: str | None = None,
        fault_plan: Optional[FaultPlan] = None,
        devices: Optional[Sequence] = None,
        sleep: Callable[[float], None] = time.sleep,
        clock: Callable[[], float] = time.perf_counter) -> SweepReport:
    """:func:`repro.core.tuning.tune_barrier` under the resilient loop:
    the full composition x placement x delay x trial grid, checkpointed
    per trial chunk."""
    from ..core import tuning
    if schedules is None:
        schedules = tuning.all_schedules(n_pes, cfg, prune=prune)
    scheds, placs = tuning._cross_placements(schedules, placements, cfg)
    return resilient_sweep_schedules(
        key, scheds, delays, n_trials, cfg, placements=placs,
        resilience=resilience, core=core, fault_plan=fault_plan,
        devices=devices, sleep=sleep, clock=clock)


def resilient_sweep_workloads(
        key, kernels: Sequence[str] | None = None,
        n_pes: int | None = None, n_trials: int = 8,
        cfg: TeraPoolConfig = DEFAULT, *, prune: str = "none",
        schedules=None, placements: Sequence[str] | None = None,
        resilience: ResilienceConfig, core: str | None = None,
        fault_plan: Optional[FaultPlan] = None,
        devices: Optional[Sequence] = None,
        sleep: Callable[[float], None] = time.sleep,
        clock: Callable[[], float] = time.perf_counter) -> SweepReport:
    """:func:`repro.core.tuning.sweep_workloads` under the resilient
    loop: every kernel's measured arrival batch (drawn exactly as the
    plain tuner draws it) across the schedule stack, checkpointed per
    trial chunk."""
    from ..core import tuning, workloads as workloads_mod
    n = int(n_pes if n_pes is not None else cfg.n_pes)
    if kernels is None:
        kernels = workloads_mod.FIG6_KERNELS
    kernels = tuple(kernels)
    if not kernels:
        raise ValueError("need at least one kernel to sweep")
    keys = jax.random.split(key, len(kernels))
    arrivals = jnp.stack([
        workloads_mod.arrival_batch(k, kernel, (n_trials, n), cfg=cfg)
        for k, kernel in zip(keys, kernels)])
    if schedules is None:
        schedules = tuning.all_schedules(n, cfg, prune=prune)
    scheds, placs = tuning._cross_placements(schedules, placements, cfg)
    return resilient_sweep_arrivals(
        arrivals, scheds, cfg, placements=placs, kernels=kernels,
        resilience=resilience, core=core, fault_plan=fault_plan,
        devices=devices, sleep=sleep, clock=clock)
