"""Tuning-as-a-service: a long-lived, thread-driven request server.

The tuner stack answers "best schedule for this kernel / N / cfg"
exhaustively, but only as one-shot batch sweeps.  This module closes
the request-serving half of the ROADMAP's Tuning-as-a-service item: a
:class:`TuningServer` accepts :class:`TuneRequest`\\ s (a named kernel
or an explicit arrival trace, an objective, a deadline, a priority),
coalesces compatible requests into ONE batched
:func:`repro.core.sweep.sweep_arrivals` dispatch on the kernel/trial
axis — one compile serves many requests — and returns
:class:`TuneResponse`\\ s with per-request provenance.

Robustness is the headline, built on the PR 6/7 crash-consistency
substrate:

* **Bounded queue with admission control** — ``queue_depth`` caps
  accepted work; an overloaded server rejects with
  :class:`ServerOverloaded` carrying a ``retry_after`` estimate
  (backpressure, not silent queueing).
* **Deadline enforcement + a three-tier degradation ladder** — a
  request whose remaining budget can't cover the EWMA-estimated sweep
  is not dropped: it degrades from (1) the *exact* batched sweep to
  (2) a persistent :mod:`~repro.runtime.schedule_cache` hit to (3) a
  *closed-form best-uniform fallback* ranked analytically over
  :func:`repro.core.barrier.all_radices` — no jit, microseconds.  Every
  response labels its tier (``"exact"`` / ``"cache"`` / ``"fallback"``).
* **Idempotent dedup** — requests are keyed on the
  :mod:`~repro.runtime.schedule_cache` digest scheme (kind, params, N,
  cfg, code version); identical in-flight requests attach to one
  pending entry and identical later requests are served from cache.
* **Retry with backoff + circuit breaker** — failed batch dispatches
  retry through :func:`repro.runtime.fault.backoff_delay`; repeated
  :class:`~repro.runtime.inject.DeviceLoss` /
  :class:`~repro.runtime.inject.SimulatedOOM` faults trip a breaker
  that serves cache/fallback-only until a probe batch succeeds.
* **Elastic dispatch** — with a :class:`ResilienceConfig` the batch
  runs through :func:`~repro.runtime.resilient_sweep.resilient_sweep_arrivals`:
  per-chunk checkpointing, straggler watchdog, and elastic re-sharding
  of the (schedule x kernel) mesh on device loss
  (:func:`repro.runtime.elastic.viable_grid_devices`).
* **Drain-based shutdown** — ``close(drain=True)`` flushes every
  in-flight batch; ``close(drain=False)`` checkpoints the undispatched
  queue to ``ckpt_dir/queue.json`` (atomic tmp + ``os.replace``) and a
  restarted server re-enqueues it, so an accepted request survives the
  restart — its ticket is answered through the degradation ladder and
  the exact result lands in the schedule cache on replay.

The batching guarantee: because the kernel axis of ``sweep_arrivals``
is a plain vmap batch dimension, the per-request slice of a batched
grid (:func:`repro.core.sweep.split_kernels`) is bit-for-bit the
result of an unbatched call — the acceptance bar of
tests/test_serving.py.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from ..core import barrier, sweep as sweep_mod, tuning, workloads
from ..core import energy as energy_mod
from ..core.topology import DEFAULT, TeraPoolConfig
from ..core import topology as topology_mod
from . import schedule_cache
from .fault import backoff_delay
from .inject import DeviceLoss, FaultPlan, SimulatedOOM
from .resilient_sweep import ResilienceConfig, resilient_sweep_arrivals

# Provenance labels: how the response was produced.
CACHE_HIT = "cache_hit"      # served from the schedule cache, no sweep
BATCHED = "batched"          # exact result from a batched sweep dispatch
DEGRADED = "degraded"        # deadline/breaker/failure forced a lower tier
FAILED = "failed"            # every tier failed (response carries error)

# Ladder tiers: which rung produced the schedule.
TIER_EXACT = "exact"         # the batched sweep itself
TIER_CACHE = "cache"         # persistent schedule_cache entry
TIER_FALLBACK = "fallback"   # closed-form best-uniform estimate
TIER_NONE = "none"           # no schedule could be produced

# Fixed seed for kernel-request arrival draws: serving is deterministic
# per (kernel, N, cfg) and independent of batch composition — each
# kernel's key is folded from its name, never from its batch slot.
_SERVING_SEED = 907


class ServerError(RuntimeError):
    """Base class of serving-side errors."""


class ServerOverloaded(ServerError):
    """Admission control rejected the request: the queue is full.

    ``retry_after`` estimates (seconds) when capacity should free up —
    clients back off instead of piling on."""

    def __init__(self, retry_after: float):
        super().__init__(
            f"queue full; retry after ~{retry_after:.2f}s")
        self.retry_after = float(retry_after)


class ServerClosed(ServerError):
    """The server is shutting down and accepts no new requests."""


@dataclasses.dataclass
class ServerConfig:
    """Knobs of the serving loop."""

    queue_depth: int = 64         # admission-control cap on pending requests
    batch_window: float = 0.02    # coalescing wait before dispatch (s)
    max_batch: int = 16           # max requests fused into one dispatch
    max_batch_retries: int = 2    # re-dispatch attempts for a failed batch
    backoff_base: float = 0.02    # fault.backoff_delay parameters for
    backoff_cap: float = 1.0      # batch retries (the resilient chunk
    backoff_jitter: float = 0.25  # loop has its own, via ResilienceConfig)
    breaker_threshold: int = 3    # consecutive faulted batches that trip it
    breaker_probe_after: float = 1.0   # open -> half-open delay (s)
    ckpt_dir: Optional[str] = None     # queue checkpoint + batch chunk stores
    resilience: Optional[ResilienceConfig] = None  # resilient dispatch
    default_n_trials: int = 8     # arrival draws for kernel requests
    ewma_alpha: float = 0.5       # batch wall-time estimator smoothing


@dataclasses.dataclass
class TuneRequest:
    """One tuning question: EITHER a named workload kernel (arrivals
    drawn from its measured model under a fixed seed) OR an explicit
    ``(n_trials, n_pes)`` arrival trace.

    ``objective`` selects the winner: ``"cycles"``, ``"energy"``,
    ``"edp"``, or ``"pareto"`` (knee of the 2-D latency x energy
    front).  ``deadline`` is a soft budget in seconds from submission —
    a request that can't make it degrades down the ladder instead of
    blocking.  Higher ``priority`` batches dispatch first."""

    kernel: Optional[str] = None
    arrivals: Optional[object] = None   # (n_trials, n_pes) array-like
    n_pes: Optional[int] = None
    cfg: TeraPoolConfig = DEFAULT
    objective: str = "cycles"
    deadline: Optional[float] = None    # seconds from submit; None = no limit
    priority: int = 0
    n_trials: Optional[int] = None      # kernel requests only
    prune: Optional[str] = None         # None = auto (hierarchy above 256 PEs)
    placements: Optional[Tuple[str, ...]] = None
    core: Optional[str] = None


@dataclasses.dataclass
class TuneResponse:
    """The answer, with full provenance: WHAT schedule, WHICH ladder
    tier produced it, and HOW (batched exactly, cache-served,
    explicitly degraded, or failed)."""

    schedule: Optional[barrier.BarrierSchedule]
    placement: object
    name: str
    objective: str
    provenance: str               # cache_hit | batched | degraded | failed
    tier: str                     # exact | cache | fallback | none
    mean_span: float = float("nan")
    mean_energy: float = float("nan")
    latency_s: float = 0.0        # submit -> response wall time
    batch_size: int = 0           # requests fused into this dispatch
    detail: str = ""              # degradation reason / error text
    result: object = None         # per-request ArrivalSweepResult (exact only)

    @property
    def ok(self) -> bool:
        return self.provenance != FAILED


@dataclasses.dataclass
class ServerStats:
    """Serving-side counters (monotonic over the server's lifetime)."""

    accepted: int = 0
    rejected: int = 0
    deduped: int = 0
    restored: int = 0             # requests re-enqueued from a queue ckpt
    batches: int = 0              # successful batch dispatches
    batch_requests: int = 0       # requests served by those dispatches
    batch_failures: int = 0       # dispatch attempts that raised
    exact: int = 0
    cache_hits: int = 0
    degraded: int = 0
    failed: int = 0
    backoff_seconds: float = 0.0
    faults: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def batch_efficiency(self) -> float:
        """Mean requests per dispatch (1.0 = no batching win)."""
        return self.batch_requests / self.batches if self.batches else 0.0


class Ticket:
    """A claim on one submitted request; ``result()`` blocks until the
    server answers (multiple identical requests share one ticket via
    dedup — every waiter sees the same response object)."""

    def __init__(self):
        self._event = threading.Event()
        self._response: Optional[TuneResponse] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> TuneResponse:
        if not self._event.wait(timeout):
            raise TimeoutError("request not answered within timeout")
        return self._response

    def _finish(self, response: TuneResponse) -> None:
        self._response = response
        self._event.set()


class _Pending:
    """One queue entry: a normalized request plus every ticket waiting
    on it (dedup attaches later identical requests here)."""

    def __init__(self, req: TuneRequest, arrivals: np.ndarray, label: str,
                 key: tuple, group: tuple, seq: int, submit_at: float):
        self.req = req
        self.arrivals = arrivals      # (n_trials, n_pes) float32
        self.label = label
        self.key = key                # schedule_cache digest key
        self.group = group            # batch-compatibility key
        self.seq = seq
        self.submit_at = submit_at
        self.deadline_at = (None if req.deadline is None
                            else submit_at + float(req.deadline))
        self.tickets: List[Ticket] = [Ticket()]

    @property
    def done(self) -> bool:
        return self.tickets[0].done()


def _auto_prune(n: int) -> str:
    return "none" if n <= 256 else "hierarchy"


def _trace_digest(arrivals: np.ndarray) -> str:
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(arrivals).tobytes())
    h.update(repr(arrivals.shape).encode())
    return h.hexdigest()[:16]


def _kernel_fold(kernel: str) -> int:
    """Stable per-kernel fold constant for the arrival-draw key."""
    return int.from_bytes(hashlib.sha256(kernel.encode()).digest()[:4],
                          "big") & 0x7FFFFFFF


def request_key(req: TuneRequest, arrivals: np.ndarray,
                n: int, trials: int, prune: str) -> tuple:
    """The idempotency / cache key of one normalized request — the same
    (kind, params, N, cfg, code-version) digest scheme every
    :mod:`~repro.runtime.schedule_cache` consumer uses, so serving
    results interoperate with the rest of the store."""
    src = (("kernel", req.kernel) if req.kernel is not None
           else ("trace", _trace_digest(arrivals)))
    return ("serve", src, int(n), repr(req.cfg), req.objective, prune,
            int(trials), req.placements, req.core)


# ---------------------------------------------------------------------------
# Tier 3: the closed-form best-uniform fallback.  No jit, no dispatch —
# an analytic span/energy estimate over every uniform radix of N, good
# enough to rank them when the exact sweep can't run in budget.
# ---------------------------------------------------------------------------

def _analytic_span(schedule: barrier.BarrierSchedule,
                   cfg: TeraPoolConfig) -> float:
    """Zero-jitter span estimate (cycles): per level, the bank
    serializes ``group_size - 1`` follower atomics plus the round trip
    and bookkeeping; plus the wakeup chain once."""
    span = float(cfg.wakeup_write + cfg.wakeup_trigger + cfg.wfi_resume)
    for lvl in schedule.levels:
        span += float(cfg.bank_service_cycles) * (lvl.group_size - 1)
        span += 2.0 * float(lvl.latency) + float(cfg.instr_per_level)
    return span


def fallback_uniform(n: int, cfg: TeraPoolConfig,
                     objective: str = "cycles"
                     ) -> Tuple[barrier.BarrierSchedule, float, float]:
    """The best uniform-radix tree for ``n`` PEs by closed-form
    estimate — the bottom rung of the degradation ladder.  Returns
    ``(schedule, est_span, est_energy)``; for ``objective="pareto"``
    the knee of the analytic (span, energy) set is picked."""
    points = []
    for k in barrier.all_radices(n, cfg):
        sched = barrier.kary_tree(k, n_pes=n, cfg=cfg)
        sp = _analytic_span(sched, cfg)
        e_static, _, idle_p = energy_mod.schedule_energy_constants(
            sched, None, cfg)
        en = float(e_static) + float(idle_p) * n * sp
        points.append((sched, sp, en))
    if not points:
        raise ValueError(f"no uniform radix divides n_pes={n}")
    if objective == "cycles":
        return min(points, key=lambda p: p[1])
    if objective == "energy":
        return min(points, key=lambda p: p[2])
    if objective == "edp":
        return min(points, key=lambda p: p[1] * p[2])
    if objective == "pareto":
        sp = np.array([p[1] for p in points])
        en = np.array([p[2] for p in points])
        ns = (sp - sp.min()) / ((sp.max() - sp.min()) or 1.0)
        ne = (en - en.min()) / ((en.max() - en.min()) or 1.0)
        return points[int(np.argmin(np.hypot(ns, ne)))]
    raise ValueError(
        f"unknown objective {objective!r}; choose from "
        f"('cycles', 'energy', 'edp', 'pareto')")


# ---------------------------------------------------------------------------
# The server.
# ---------------------------------------------------------------------------

class TuningServer:
    """See the module docstring.  Thread-safe: ``submit``/``tune`` may
    be called from any number of client threads; one worker thread
    drains the queue.  Use as a context manager for drain-on-exit:

        with TuningServer(ServerConfig(...)) as srv:
            resp = srv.tune(TuneRequest(kernel="dotp_1Mi", n_pes=1024))
    """

    def __init__(self, config: Optional[ServerConfig] = None, *,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 fault_plan: Optional[FaultPlan] = None,
                 devices: Optional[Sequence] = None,
                 start: bool = True):
        self.config = config or ServerConfig()
        self.stats = ServerStats()
        self._clock = clock
        self._sleep = sleep
        self._fault_plan = fault_plan
        self._devices = devices
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._queue: List[_Pending] = []
        self._processing = False
        self._closing = False
        self._drain = True
        self._seq = 0
        self._n_dispatches = 0
        self._ewma: Optional[float] = None
        self._memo: Dict[tuple, dict] = {}      # in-process payload cache
        self._stacks: Dict[tuple, tuple] = {}   # group -> (scheds, placs)
        self._breaker_failures = 0
        self._breaker_open_since: Optional[float] = None
        self._breaker_probing = False
        self._thread: Optional[threading.Thread] = None
        self._restore_queue()
        if start:
            self.start()

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "TuningServer":
        with self._lock:
            if self._closing:
                raise ServerClosed("server already closed")
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._serve_loop, name="tuning-server",
                    daemon=True)
                self._thread.start()
        return self

    def close(self, drain: bool = True,
              timeout: Optional[float] = None) -> None:
        """Stop the server.  ``drain=True`` answers every pending
        request exactly (flushing in-flight batches) before returning;
        ``drain=False`` checkpoints the undispatched queue to
        ``ckpt_dir/queue.json`` for the next server instance and
        answers the parked tickets through the degradation ladder."""
        with self._cond:
            self._closing = True
            self._drain = bool(drain)
            parked: List[_Pending] = []
            if not drain:
                parked, self._queue = self._queue, []
            elif self._queue and self._thread is None:
                # Never-started server with queued work: drain needs a
                # worker after all.
                self._thread = threading.Thread(
                    target=self._serve_loop, name="tuning-server",
                    daemon=True)
                self._thread.start()
            self._cond.notify_all()
        if parked:
            self._checkpoint_queue(parked)
            for p in parked:
                self._degrade(p, "server shutdown: request checkpointed "
                                 "for replay at restart")
        if self._thread is not None:
            self._thread.join(timeout)
            if self._thread.is_alive():
                raise TimeoutError("serving thread did not stop in time")

    def __enter__(self) -> "TuningServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close(drain=True)

    def flush(self, timeout: Optional[float] = None) -> None:
        """Block until the queue is empty and no batch is in flight."""
        end = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while self._queue or self._processing:
                remaining = None if end is None else end - time.monotonic()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError("queue did not drain in time")
                self._cond.wait(0.05 if remaining is None
                                else min(0.05, remaining))

    # -- client API ---------------------------------------------------------

    def submit(self, req: TuneRequest) -> Ticket:
        """Admit one request; returns a :class:`Ticket` immediately.
        Raises :class:`ServerOverloaded` (with ``retry_after``) when the
        queue is full and :class:`ServerClosed` after shutdown began."""
        pending = self._normalize(req)
        with self._cond:
            if self._closing:
                raise ServerClosed("server is shutting down")
            for other in self._queue:
                if other.key == pending.key:
                    ticket = Ticket()
                    other.tickets.append(ticket)
                    self.stats.deduped += 1
                    return ticket
            if len(self._queue) >= self.config.queue_depth:
                self.stats.rejected += 1
                raise ServerOverloaded(self._retry_after_locked())
            self.stats.accepted += 1
            self._queue.append(pending)
            self._cond.notify_all()
        return pending.tickets[0]

    def tune(self, req: TuneRequest,
             timeout: Optional[float] = None) -> TuneResponse:
        """Convenience: ``submit`` + blocking ``result``."""
        return self.submit(req).result(timeout)

    @property
    def breaker_state(self) -> str:
        """``"closed"`` | ``"open"`` | ``"half_open"`` (probe-ready)."""
        if self._breaker_open_since is None:
            return "closed"
        if (self._clock() - self._breaker_open_since
                >= self.config.breaker_probe_after):
            return "half_open"
        return "open"

    # -- request normalization ---------------------------------------------

    def _normalize(self, req: TuneRequest) -> _Pending:
        if (req.kernel is None) == (req.arrivals is None):
            raise ValueError(
                "a TuneRequest needs exactly one of kernel= or arrivals=")
        if req.objective not in ("cycles", "energy", "edp", "pareto"):
            raise ValueError(
                f"unknown objective {req.objective!r}; choose from "
                f"('cycles', 'energy', 'edp', 'pareto')")
        if req.kernel is not None:
            if req.kernel not in workloads.ARRIVAL_KERNELS:
                raise ValueError(
                    f"unknown kernel {req.kernel!r}; choose from "
                    f"{workloads.ARRIVAL_KERNELS}")
            n = int(req.n_pes or req.cfg.n_pes)
            trials = int(req.n_trials or self.config.default_n_trials)
            key = jax.random.fold_in(jax.random.PRNGKey(_SERVING_SEED),
                                     _kernel_fold(req.kernel))
            arrivals = np.asarray(
                workloads.arrival_batch(key, req.kernel, (trials, n),
                                        req.cfg), np.float32)
            label = req.kernel
        else:
            arrivals = np.asarray(req.arrivals, np.float32)
            if arrivals.ndim == 1:
                arrivals = arrivals[None]
            if arrivals.ndim != 2:
                raise ValueError(
                    f"arrivals must be (n_trials, n_pes), got shape "
                    f"{arrivals.shape}")
            if req.n_pes is not None and int(req.n_pes) != arrivals.shape[-1]:
                raise ValueError(
                    f"n_pes={req.n_pes} but the trace has "
                    f"{arrivals.shape[-1]} PEs")
            n = arrivals.shape[-1]
            trials = arrivals.shape[0]
            label = f"trace:{_trace_digest(arrivals)[:8]}"
        prune = req.prune or _auto_prune(n)
        key = request_key(req, arrivals, n, trials, prune)
        group = (n, repr(req.cfg), prune, trials, req.placements, req.core)
        with self._lock:
            seq = self._seq
            self._seq += 1
        return _Pending(req, arrivals, label, key, group, seq,
                        self._clock())

    def _retry_after_locked(self) -> float:
        per_batch = max(self._ewma or 0.0, self.config.batch_window)
        batches_ahead = 1 + len(self._queue) // max(1, self.config.max_batch)
        return per_batch * batches_ahead

    # -- worker -------------------------------------------------------------

    def _serve_loop(self) -> None:
        while True:
            with self._cond:
                while not self._queue and not self._closing:
                    self._cond.wait(0.1)
                if not self._queue:
                    return                   # closing and fully drained
                if not self._closing and self.config.batch_window > 0:
                    self._cond.wait(self.config.batch_window)
                if not self._queue:
                    continue     # drained by a non-drain close mid-wait
                batch = self._take_batch_locked()
                self._processing = True
            try:
                self._process(batch)
            except BaseException as e:       # never kill the worker
                for p in batch:
                    if not p.done:
                        self._finish(p, TuneResponse(
                            schedule=None, placement=None, name="",
                            objective=p.req.objective, provenance=FAILED,
                            tier=TIER_NONE, detail=f"internal error: {e!r}"))
            finally:
                with self._cond:
                    self._processing = False
                    self._cond.notify_all()

    def _take_batch_locked(self) -> List[_Pending]:
        self._queue.sort(key=lambda p: (-p.req.priority, p.seq))
        group = self._queue[0].group
        batch, rest = [], []
        for p in self._queue:
            if len(batch) < self.config.max_batch and p.group == group:
                batch.append(p)
            else:
                rest.append(p)
        self._queue = rest
        return batch

    def _process(self, batch: List[_Pending]) -> None:
        now = self._clock()
        todo = []
        for p in batch:
            payload = self._cached(p.key)
            if payload is not None:
                self._finish_from_payload(p, payload, CACHE_HIT, TIER_CACHE)
                continue
            todo.append(p)
        ready = []
        for p in todo:
            if p.deadline_at is not None:
                remaining = p.deadline_at - now
                estimate = self._ewma or 0.0
                if remaining <= estimate:
                    self._degrade(
                        p, f"deadline: {remaining:.3f}s budget left, "
                           f"sweep estimated at {estimate:.3f}s")
                    continue
            ready.append(p)
        if not ready:
            return
        if not self._breaker_allows():
            for p in ready:
                self._degrade(p, "circuit breaker open: serving "
                                 "cache/fallback only")
            return
        t0 = self._clock()
        try:
            res, fault_counts = self._dispatch(ready)
        except Exception as e:
            self._note_batch_outcome(ok=False, fault_counts={})
            for p in ready:
                self._degrade(p, f"batch dispatch failed: {e}")
            return
        dt = self._clock() - t0
        a = self.config.ewma_alpha
        self._ewma = dt if self._ewma is None else a * dt + (1 - a) * self._ewma
        self._note_batch_outcome(ok=True, fault_counts=fault_counts)
        self.stats.batches += 1
        self.stats.batch_requests += len(ready)
        winners = tuning.best_for_arrival_stack(
            res, tuple(p.req.objective for p in ready))
        slices = sweep_mod.split_kernels(res)
        for p, win, piece in zip(ready, winners, slices):
            payload = {
                "pair": schedule_cache.encode_pair(
                    win.schedule, win.placement, objective=p.req.objective),
                "name": win.name,
                "mean_span": win.mean_span,
                "mean_energy": win.mean_energy,
            }
            self._memo[p.key] = payload
            schedule_cache.store(p.key, payload)
            self.stats.exact += 1
            self._finish(p, TuneResponse(
                schedule=win.schedule, placement=win.placement,
                name=win.name, objective=p.req.objective,
                provenance=BATCHED, tier=TIER_EXACT,
                mean_span=win.mean_span, mean_energy=win.mean_energy,
                batch_size=len(ready), result=piece))

    # -- dispatch -----------------------------------------------------------

    def _stack_for(self, sample: _Pending) -> tuple:
        if sample.group not in self._stacks:
            n, _, prune, _, placements, _ = sample.group
            schedules = tuning.all_schedules(n, sample.req.cfg, prune=prune)
            scheds, placs = tuning._cross_placements(
                schedules, placements, sample.req.cfg)
            self._stacks[sample.group] = (scheds, placs)
        return self._stacks[sample.group]

    def _dispatch(self, ready: List[_Pending]):
        """One batched sweep over every request in ``ready`` (same
        group), with retry + backoff.  Returns ``(result,
        fault_counts)``; raises after ``max_batch_retries``."""
        scheds, placs = self._stack_for(ready[0])
        arrivals = np.stack([p.arrivals for p in ready])
        labels = tuple(p.label for p in ready)
        cfg = ready[0].req.cfg
        core = ready[0].req.core
        rcfg = self._batch_resilience()
        attempt = 0
        while True:
            idx = self._n_dispatches
            self._n_dispatches += 1
            try:
                if self._fault_plan is not None and rcfg is None:
                    # The resilient path feeds the plan to its own chunk
                    # boundaries; the plain path fires it here.
                    self._fault_plan.at_chunk(idx)
                if rcfg is not None:
                    rep = resilient_sweep_arrivals(
                        arrivals, scheds, cfg, placements=placs,
                        kernels=labels, resilience=rcfg, core=core,
                        fault_plan=self._fault_plan, devices=self._devices,
                        sleep=self._sleep)
                    self.stats.backoff_seconds += rep.backoff_seconds
                    return rep.result, dict(rep.fault_counts)
                res = sweep_mod.sweep_arrivals(
                    arrivals, scheds, cfg, placements=placs,
                    kernels=labels, core=core, devices=self._devices)
                return res, {}
            except Exception as e:
                cls = type(e).__name__
                self.stats.faults[cls] = self.stats.faults.get(cls, 0) + 1
                self.stats.batch_failures += 1
                if attempt >= self.config.max_batch_retries:
                    raise
                delay = backoff_delay(attempt,
                                      base=self.config.backoff_base,
                                      cap=self.config.backoff_cap,
                                      jitter=self.config.backoff_jitter)
                self.stats.backoff_seconds += delay
                self._sleep(delay)
                attempt += 1

    def _batch_resilience(self) -> Optional[ResilienceConfig]:
        rcfg = self.config.resilience
        if rcfg is None:
            return None
        # Each dispatch gets its own chunk store under the configured
        # root; retries of the same batch reuse it (resume, not redo).
        sub = os.path.join(rcfg.ckpt_dir, f"batch{self._n_dispatches:06d}")
        return dataclasses.replace(rcfg, ckpt_dir=sub)

    # -- circuit breaker ----------------------------------------------------

    def _breaker_allows(self) -> bool:
        if self._breaker_open_since is None:
            return True
        if (self._clock() - self._breaker_open_since
                >= self.config.breaker_probe_after):
            self._breaker_probing = True     # half-open: one probe batch
            return True
        return False

    def _note_batch_outcome(self, ok: bool,
                            fault_counts: Dict[str, int]) -> None:
        for cls, count in fault_counts.items():
            self.stats.faults[cls] = self.stats.faults.get(cls, 0) + count
        breaker_faults = (fault_counts.get(DeviceLoss.__name__, 0)
                          + fault_counts.get(SimulatedOOM.__name__, 0))
        if ok and breaker_faults == 0:
            self._breaker_failures = 0
            self._breaker_open_since = None
        else:
            self._breaker_failures += 1
            if (self._breaker_failures >= self.config.breaker_threshold
                    or self._breaker_probing):
                self._breaker_open_since = self._clock()
        self._breaker_probing = False

    # -- the degradation ladder ---------------------------------------------

    def _cached(self, key: tuple) -> Optional[dict]:
        payload = self._memo.get(key)
        if payload is None:
            payload = schedule_cache.load(key)
            if payload is not None:
                self._memo[key] = payload
        return payload

    def _finish_from_payload(self, p: _Pending, payload: dict,
                             provenance: str, tier: str,
                             detail: str = "") -> None:
        sched, plc = schedule_cache.decode_pair(payload["pair"], p.req.cfg)
        if provenance == CACHE_HIT:
            self.stats.cache_hits += 1
        else:
            self.stats.degraded += 1
        self._finish(p, TuneResponse(
            schedule=sched, placement=plc,
            name=payload.get("name",
                             barrier.schedule_name(sched, plc)),
            objective=p.req.objective, provenance=provenance, tier=tier,
            mean_span=float(payload.get("mean_span", float("nan"))),
            mean_energy=float(payload.get("mean_energy", float("nan"))),
            detail=detail))

    def _degrade(self, p: _Pending, reason: str) -> None:
        """Tiers 2-3: cache hit, else closed-form best-uniform.  A
        degraded response is always labeled, never silently wrong, and
        never dropped."""
        payload = self._cached(p.key)
        if payload is not None:
            self._finish_from_payload(p, payload, DEGRADED, TIER_CACHE,
                                      detail=reason)
            return
        try:
            sched, sp, en = fallback_uniform(
                p.arrivals.shape[-1], p.req.cfg, p.req.objective)
            self.stats.degraded += 1
            self._finish(p, TuneResponse(
                schedule=sched, placement=None,
                name=barrier.schedule_name(sched),
                objective=p.req.objective, provenance=DEGRADED,
                tier=TIER_FALLBACK, mean_span=sp, mean_energy=en,
                detail=reason))
        except Exception as e:
            self.stats.failed += 1
            self._finish(p, TuneResponse(
                schedule=None, placement=None, name="",
                objective=p.req.objective, provenance=FAILED,
                tier=TIER_NONE, detail=f"{reason}; fallback failed: {e}"))

    def _finish(self, p: _Pending, response: TuneResponse) -> None:
        response.latency_s = self._clock() - p.submit_at
        for ticket in p.tickets:
            ticket._finish(response)

    # -- queue checkpoint ---------------------------------------------------

    def _queue_ckpt_path(self) -> Optional[Path]:
        if self.config.ckpt_dir is None:
            return None
        return Path(self.config.ckpt_dir) / "queue.json"

    def _checkpoint_queue(self, parked: List[_Pending]) -> None:
        path = self._queue_ckpt_path()
        if path is None or not parked:
            return
        path.parent.mkdir(parents=True, exist_ok=True)
        entries = [self._encode_request(p.req) for p in parked]
        tmp = path.with_suffix(".tmp")
        tmp.write_text(json.dumps(entries, indent=1))
        os.replace(tmp, path)

    def _restore_queue(self) -> None:
        path = self._queue_ckpt_path()
        if path is None or not path.exists():
            return
        try:
            entries = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError):
            return
        try:
            path.unlink()
        except OSError:
            pass
        for entry in entries:
            try:
                req = self._decode_request(entry)
                self._queue.append(self._normalize(req))
                self.stats.restored += 1
            except Exception:
                continue              # an unrestorable entry is dropped

    @staticmethod
    def _encode_request(req: TuneRequest) -> dict:
        d = {"objective": req.objective, "priority": req.priority,
             "n_pes": req.n_pes, "n_trials": req.n_trials,
             "prune": req.prune, "core": req.core,
             "placements": (list(req.placements)
                            if req.placements is not None else None),
             "cfg_class": type(req.cfg).__name__,
             "cfg": dataclasses.asdict(req.cfg)}
        if req.kernel is not None:
            d["kernel"] = req.kernel
        else:
            d["arrivals"] = np.asarray(req.arrivals,
                                       np.float32).tolist()
        return d

    @staticmethod
    def _decode_request(entry: dict) -> TuneRequest:
        cls = getattr(topology_mod, entry["cfg_class"])
        cfg = cls(**entry["cfg"])
        placements = entry.get("placements")
        return TuneRequest(
            kernel=entry.get("kernel"),
            arrivals=(np.asarray(entry["arrivals"], np.float32)
                      if "arrivals" in entry else None),
            n_pes=entry.get("n_pes"), cfg=cfg,
            objective=entry.get("objective", "cycles"),
            deadline=None,            # budgets don't survive a restart
            priority=int(entry.get("priority", 0)),
            n_trials=entry.get("n_trials"), prune=entry.get("prune"),
            placements=(tuple(placements) if placements else None),
            core=entry.get("core"))
