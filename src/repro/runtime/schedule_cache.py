"""Persistent, checksummed on-disk schedule cache.

The tuner's in-process stores (``functools.lru_cache`` on
:func:`repro.core.tuning.tuned_for_workload` and the 5G mode caches in
:mod:`repro.core.fiveg`) die with the process — a serving deployment
re-runs the full composition x placement sweep for every worker
restart.  This module promotes those stores to a shared on-disk layer:

* **Keyed on (kind, params, n_pes, cfg, code-version)** — the code
  version is a digest of the simulator/tuner sources, so a cache
  written by an older physics model is silently invalidated instead of
  served (a tuned schedule is only as good as the simulator that
  picked it).
* **Atomic** — entries are published with the same tmp + ``os.replace``
  pattern as checkpoints; concurrent writers race benignly (last
  writer wins with a complete file, readers never see a torn entry).
* **Checksummed** — every entry embeds a SHA-256 over its payload; a
  corrupt or truncated entry is detected, dropped and recomputed,
  never trusted (the acceptance bar of tests/test_resilience.py).

The cache activates when ``REPRO_SCHEDULE_CACHE`` names a directory;
unset, every consumer falls back to its in-memory store only (tests
stay hermetic).  Payloads hold *encoded* schedules/placements —
:func:`encode_schedule` round-trips any
:class:`~repro.core.barrier.BarrierSchedule` through its level sizes
(the schedule algebra re-derives spans and latencies from ``cfg``),
and placements through their explicit bank/latency tables.

The store is additionally BOUNDED: ``REPRO_SCHEDULE_CACHE_TTL``
(seconds) expires entries by age and ``REPRO_SCHEDULE_CACHE_MAX``
(entry count) applies LRU eviction on store — both mtime-based (a hit
touches its entry's mtime, so recently served schedules survive the
cap), both off when unset, both counted in ``STATS["evictions"]``.
"""
from __future__ import annotations

import functools
import hashlib
import json
import os
import tempfile
import time
from pathlib import Path
from typing import Optional, Tuple

# Environment knob naming the cache directory; unset == disabled.
CACHE_ENV = "REPRO_SCHEDULE_CACHE"
# Entry time-to-live in seconds (float); unset/empty == entries never
# expire.  Age is measured from the entry file's mtime, which doubles
# as the LRU clock (hits re-touch it).
TTL_ENV = "REPRO_SCHEDULE_CACHE_TTL"
# Maximum entry count (int); unset/empty == unbounded.  Enforced on
# every ``store`` by evicting least-recently-used entries first.
MAX_ENV = "REPRO_SCHEDULE_CACHE_MAX"

# Process-level cache traffic counters (reset with ``reset_stats``).
# ``races`` counts tolerated ``FileNotFoundError`` windows — an entry
# (or the whole cache root) vanishing between our check and our use,
# e.g. a concurrent ``evict`` in another process.  A race is a benign
# miss, never a corruption and never a crash.
STATS = {"hits": 0, "misses": 0, "corrupt": 0, "stores": 0,
         "evictions": 0, "races": 0}


def reset_stats() -> None:
    for k in STATS:
        STATS[k] = 0


def cache_dir() -> Optional[Path]:
    """The active cache directory, or ``None`` when caching is off.
    Read per call so tests (and operators) can flip the env var."""
    d = os.environ.get(CACHE_ENV)
    return Path(d) if d else None


def _env_number(name: str, cast) -> Optional[float]:
    """The env knob as a number, or ``None`` when unset/empty/invalid
    (a malformed limit must never take the cache down)."""
    raw = os.environ.get(name)
    if not raw:
        return None
    try:
        val = cast(raw)
    except ValueError:
        return None
    return val if val > 0 else None


def _expired(path: Path, now: float) -> bool:
    """Entry older than the TTL (``False`` when no TTL is set)."""
    ttl = _env_number(TTL_ENV, float)
    if ttl is None:
        return False
    try:
        return now - path.stat().st_mtime > ttl
    except FileNotFoundError:
        raise                        # vanished concurrently: caller's race
    except OSError:
        return True


def evict(now: Optional[float] = None) -> int:
    """Apply the TTL and LRU-size policies to the store: drop expired
    entries, then the least-recently-used entries beyond the
    ``REPRO_SCHEDULE_CACHE_MAX`` cap (mtime is the LRU clock — hits
    touch it).  Returns the number of entries evicted; called on every
    :func:`store`, callable directly by operators."""
    root = cache_dir()
    if root is None or not root.is_dir():
        return 0
    now = time.time() if now is None else now
    entries = []
    dropped = 0
    for path in root.glob("*.json"):
        try:
            expired = _expired(path, now)
        except FileNotFoundError:
            STATS["races"] += 1      # another process beat us to it
            continue
        if expired:
            try:
                path.unlink()
                dropped += 1
            except OSError:
                pass
            continue
        try:
            entries.append((path.stat().st_mtime, path))
        except OSError:
            pass
    cap = _env_number(MAX_ENV, int)
    if cap is not None and len(entries) > cap:
        entries.sort()               # oldest mtime first == LRU first
        for _, path in entries[:len(entries) - int(cap)]:
            try:
                path.unlink()
                dropped += 1
            except OSError:
                pass
    STATS["evictions"] += dropped
    return dropped


@functools.lru_cache(maxsize=1)
def code_version() -> str:
    """Digest of every source file the tuned result depends on: the
    simulator cores, the schedule/placement algebra, the sweep engine,
    the tuner and the workload models.  Any edit to the physics
    invalidates every cached schedule."""
    from ..core import (barrier, barrier_sim, energy, placement, sweep,
                        topology, tuning, workloads)
    h = hashlib.sha256()
    for mod in (barrier, barrier_sim, energy, placement, sweep, topology,
                tuning, workloads):
        h.update(Path(mod.__file__).read_bytes())
        h.update(b"\x00")
    return h.hexdigest()[:16]


def _key_repr(key: tuple) -> str:
    return repr(tuple(key) + ("code", code_version()))


def _entry_path(root: Path, key: tuple) -> Path:
    digest = hashlib.sha256(_key_repr(key).encode()).hexdigest()[:32]
    return root / f"{digest}.json"


def _payload_checksum(payload: dict) -> str:
    blob = json.dumps(payload, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


def load(key: tuple) -> Optional[dict]:
    """The cached payload for ``key``, or ``None`` on miss.  Corrupt
    entries (unparseable, checksum mismatch, digest collision) count in
    ``STATS["corrupt"]``, are unlinked, and read as a miss."""
    root = cache_dir()
    if root is None:
        return None
    path = _entry_path(root, key)
    if not path.exists():
        STATS["misses"] += 1
        return None
    try:
        expired = _expired(path, time.time())
    except FileNotFoundError:
        # Evicted/unlinked between the exists() check and the stat():
        # a plain miss, not a corruption (concurrent-writer bar of
        # tests/test_resilience.py).
        STATS["races"] += 1
        STATS["misses"] += 1
        return None
    if expired:
        try:
            path.unlink()
        except OSError:
            pass
        STATS["evictions"] += 1
        STATS["misses"] += 1
        return None
    try:
        entry = json.loads(path.read_text())
        payload = entry["payload"]
        if entry["sha256"] != _payload_checksum(payload):
            raise ValueError("payload checksum mismatch")
        if entry["key"] != _key_repr(key):
            raise ValueError("key mismatch (digest collision?)")
    except FileNotFoundError:
        STATS["races"] += 1          # vanished between stat and read
        STATS["misses"] += 1
        return None
    except (OSError, json.JSONDecodeError, KeyError, TypeError,
            ValueError, UnicodeDecodeError):
        STATS["corrupt"] += 1
        try:
            path.unlink()
        except OSError:
            pass
        return None
    STATS["hits"] += 1
    try:
        os.utime(path)               # LRU touch: a hit is recent use
    except OSError:
        pass
    return payload


def store(key: tuple, payload: dict) -> None:
    """Atomically publish ``payload`` under ``key`` (no-op when the
    cache is disabled).

    Tolerates the cache root vanishing mid-publish (a concurrent
    teardown or operator ``rm -rf``): the publish is retried once after
    re-creating the root, then given up silently — a lost cache entry
    must never take the tuner down."""
    root = cache_dir()
    if root is None:
        return
    entry = {"key": _key_repr(key),
             "sha256": _payload_checksum(payload),
             "payload": payload}
    blob = json.dumps(entry, indent=1)
    for attempt in range(2):
        root.mkdir(parents=True, exist_ok=True)
        try:
            fd, tmp = tempfile.mkstemp(dir=root, suffix=".tmp")
        except FileNotFoundError:
            STATS["races"] += 1
            continue
        try:
            with os.fdopen(fd, "w") as f:
                f.write(blob)
            os.replace(tmp, _entry_path(root, key))
        except FileNotFoundError:
            STATS["races"] += 1
            try:
                os.unlink(tmp)
            except OSError:
                pass
            continue
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        STATS["stores"] += 1
        evict()
        return


# ---------------------------------------------------------------------------
# Schedule / placement codecs.
# ---------------------------------------------------------------------------

def encode_schedule(schedule) -> dict:
    """JSON form of a schedule: its level sizes + partial flag (spans
    and latencies are re-derived from ``cfg`` on decode, so the codec
    round-trips every constructor — kary/central/partial/mixed), plus
    the ``hw`` event-unit flag (hw schedules re-derive their stage
    structure from ``cfg`` too)."""
    out = {"sizes": list(schedule.sizes), "partial": bool(schedule.partial)}
    if getattr(schedule, "hw", False):
        out["hw"] = True
        out["n_pes"] = int(schedule.n_pes)
    return out


def decode_schedule(payload: dict, cfg):
    from ..core import barrier
    if payload.get("hw"):
        return barrier.hw_event_unit(int(payload["n_pes"]), cfg=cfg)
    return barrier.mixed_radix_tree(tuple(int(s) for s in payload["sizes"]),
                                    cfg=cfg, partial=bool(payload["partial"]))


def encode_placement(placement) -> Optional[dict]:
    if placement is None:
        return None
    return {"strategy": placement.strategy,
            "banks": [list(row) for row in placement.banks],
            "latencies": [list(row) for row in placement.latencies]}


def decode_placement(payload: Optional[dict]):
    if payload is None:
        return None
    from ..core.placement import CounterPlacement
    return CounterPlacement(
        strategy=str(payload["strategy"]),
        banks=tuple(tuple(int(b) for b in row)
                    for row in payload["banks"]),
        latencies=tuple(tuple(int(x) for x in row)
                        for row in payload["latencies"]))


def encode_pair(schedule, placement, objective: str = "cycles") -> dict:
    """Encoded (schedule, placement) pair; ``objective`` records WHICH
    metric picked this winner ("cycles", "energy", "edp" or "pareto"),
    so operators can tell a latency-tuned entry from an energy-tuned
    one when auditing the store."""
    return {"schedule": encode_schedule(schedule),
            "placement": encode_placement(placement),
            "objective": str(objective)}


def decode_pair(payload: dict, cfg) -> Tuple:
    """Decode :func:`encode_pair` (tolerant of pre-energy entries that
    lack the ``objective`` field)."""
    return (decode_schedule(payload["schedule"], cfg),
            decode_placement(payload["placement"]))


def pair_objective(payload: dict) -> str:
    """The objective recorded in an encoded pair ("cycles" for legacy
    entries written before the energy subsystem)."""
    return str(payload.get("objective", "cycles"))
