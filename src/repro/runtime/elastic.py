"""Elastic re-meshing: rebuild the device mesh from whatever is alive.

At thousand-node scale, node loss is routine.  Because every sharding
in this framework is *derived* from the mesh at step-build time
(launch/steps.py), elasticity reduces to: pick the largest supported
mesh that fits the surviving devices, rebuild the step function, and
restore parameters from the latest checkpoint (which stores unsharded
logical arrays).  Nothing else in the stack knows the mesh size.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax


def viable_mesh_shape(n_devices: int, *, model_parallel: int,
                      min_data: int = 1) -> Optional[Tuple[int, int]]:
    """Largest (data, model) grid that fits ``n_devices`` while keeping
    the TP degree fixed (weights must still fit per device)."""
    if n_devices < model_parallel * min_data:
        return None
    data = n_devices // model_parallel
    # power-of-two data axis keeps batch divisibility simple
    data = 1 << int(math.log2(data))
    return (data, model_parallel)


def make_elastic_mesh(*, model_parallel: int,
                      devices: Optional[Sequence] = None):
    """Build the biggest healthy mesh available right now."""
    devices = list(devices if devices is not None else jax.devices())
    shape = viable_mesh_shape(len(devices), model_parallel=model_parallel)
    if shape is None:
        raise RuntimeError(
            f"only {len(devices)} devices alive; need >= {model_parallel}")
    data, model = shape
    used = devices[: data * model]
    import numpy as np
    arr = np.array(used).reshape(data, model)
    mesh = jax.sharding.Mesh(arr, ("data", "model"))
    return mesh


def viable_schedule_devices(devices: Sequence, n_schedules: int, *,
                            min_devices: int = 1) -> Optional[tuple]:
    """Largest prefix of ``devices`` whose size divides the schedule
    axis — the 1-D sibling of :func:`viable_mesh_shape` for the barrier
    sweeps, whose only sharded axis is the schedule stack
    (:mod:`repro.core.sweep` ``shard_map``s over ``("sched",)``).

    After a device loss the resilient sweep runtime
    (:mod:`repro.runtime.resilient_sweep`) calls this with the
    survivors: the sweep continues on the biggest mesh that still
    divides the stack evenly (1 device — the transparent unsharded
    fallback — always qualifies when ``min_devices <= 1``).  Returns
    ``None`` when fewer than ``min_devices`` devices remain viable.
    """
    if n_schedules < 1:
        raise ValueError(f"need a non-empty schedule axis, got "
                         f"{n_schedules}")
    for d in range(len(devices), min_devices - 1, -1):
        if d >= 1 and n_schedules % d == 0:
            return tuple(devices[:d])
    return None


def viable_grid_devices(devices: Sequence, n_schedules: int,
                        n_kernels: int, *,
                        min_devices: int = 1) -> Optional[tuple]:
    """Largest usable prefix of ``devices`` for a 2-D
    (schedule x kernel) arrival grid — the 2-D sibling of
    :func:`viable_schedule_devices`.

    Delegates the shape choice to the sweep dispatcher's own
    :func:`repro.core.sweep._mesh_shape` so the survivors re-shard
    exactly the way a fresh launch would (schedule axis preferred,
    kernel axis picking up the slack).  Returns the ``ds * dk``-device
    prefix, or ``None`` when fewer than ``min_devices`` remain viable.
    """
    from ..core.sweep import _mesh_shape
    if n_schedules < 1:
        raise ValueError(f"need a non-empty schedule axis, got "
                         f"{n_schedules}")
    if n_kernels < 1:
        raise ValueError(f"need a non-empty kernel axis, got {n_kernels}")
    ds, dk = _mesh_shape(len(devices), n_schedules, n_kernels)
    if ds * dk < max(1, min_devices):
        return None
    return tuple(devices[:ds * dk])


def rescale_batch(global_batch: int, old_data: int, new_data: int) -> int:
    """Keep per-device batch constant across a re-mesh (synchronous DP
    semantics: the optimizer sees a smaller global batch until capacity
    returns; lr rescaling is the caller's policy)."""
    per_device = global_batch // old_data
    return per_device * new_data
