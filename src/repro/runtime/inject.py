"""Deterministic fault injection for the resilient sweep runtime.

At thousand-PE scale partial failure is the common case (MemPool,
arXiv 2303.17742; the multi-cluster scaling study, arXiv 2507.05012),
but real faults are useless for testing: they are neither repeatable
nor CPU-portable.  This module makes them both.  A :class:`FaultPlan`
binds simulated faults to *chunk boundaries* of the chunked sweep loop
(:mod:`repro.runtime.resilient_sweep`): right before the driver starts
chunk ``i`` it calls :meth:`FaultPlan.at_chunk`, which raises the
planned fault exactly once — so a test can kill a sweep at ANY chosen
boundary, resume it, and assert bit-for-bit equality with the
uninterrupted run.

Fault taxonomy (all subclasses of :class:`SimulatedFault`):

* :class:`DeviceLoss` — ``n_lost`` devices disappear.  Non-fatal: the
  supervisor shrinks the schedule-axis mesh to the survivors
  (:func:`repro.runtime.elastic.viable_schedule_devices`) and retries.
* :class:`SimulatedOOM` — a transient allocator failure.  Non-fatal:
  plain backoff + retry, same mesh.
* :class:`Preemption` — a hard kill (SIGKILL / spot reclaim).  FATAL:
  re-raised to the caller like real process death; a subsequent call
  with the same checkpoint directory resumes from the last completed
  chunk.

``straggle`` entries inflate the *measured* wall time of a chunk by a
fixed number of seconds (fire-once, like faults) so the per-chunk
straggler watchdog can be driven deterministically without sleeping.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List


class SimulatedFault(RuntimeError):
    """Base class of all injected faults.

    ``fatal`` faults simulate process death: the resilient driver
    re-raises them instead of restarting, and recovery happens on the
    NEXT call against the same checkpoint directory.  Non-fatal faults
    are handled in-process by the supervisor loop (backoff + retry,
    elastic re-shard on device loss)."""

    fatal = False

    def __init__(self, msg: str = "injected fault"):
        super().__init__(msg)


class DeviceLoss(SimulatedFault):
    """``n_lost`` devices vanish at a chunk boundary."""

    def __init__(self, n_lost: int = 1):
        super().__init__(f"injected device loss ({n_lost} device(s))")
        if n_lost < 1:
            raise ValueError(f"n_lost must be >= 1, got {n_lost}")
        self.n_lost = int(n_lost)


class SimulatedOOM(SimulatedFault):
    """Transient out-of-memory: retry (possibly after backoff) succeeds."""

    def __init__(self):
        super().__init__("injected out-of-memory")


class Preemption(SimulatedFault):
    """Hard preemption: kills the sweep like SIGKILL — no in-process
    recovery; the next call resumes from the checkpoint."""

    fatal = True

    def __init__(self):
        super().__init__("injected preemption (hard kill)")


@dataclasses.dataclass
class FaultPlan:
    """A deterministic schedule of faults over chunk indices.

    ``faults[i]`` is raised when the driver reaches the boundary BEFORE
    chunk ``i`` (chunks ``< i`` are already checkpointed at that
    point); ``straggle[i]`` adds that many simulated seconds to chunk
    ``i``'s measured duration.  Every entry fires exactly once — the
    retry (or the resumed call, for fatal faults) sails past it, which
    is what makes kill-at-every-boundary sweep tests terminate.
    ``fired`` records what actually triggered, for reports.
    """

    faults: Dict[int, SimulatedFault] = dataclasses.field(
        default_factory=dict)
    straggle: Dict[int, float] = dataclasses.field(default_factory=dict)
    fired: List[str] = dataclasses.field(default_factory=list)
    _done: set = dataclasses.field(default_factory=set, repr=False)

    def __post_init__(self):
        """Reject malformed plans at construction, not mid-sweep: a
        typo'd plan that silently never fires (or fires something that
        isn't a fault) invalidates whatever resilience property the
        test thought it proved."""
        seen: Dict[int, SimulatedFault] = {}
        for idx, fault in self.faults.items():
            self._check_index(idx, "faults")
            if not isinstance(fault, SimulatedFault):
                raise ValueError(
                    f"unknown fault kind at chunk {idx}: expected a "
                    f"SimulatedFault (DeviceLoss / SimulatedOOM / "
                    f"Preemption), got {type(fault).__name__}: {fault!r}")
            dup = next((j for j, f in seen.items() if f is fault), None)
            if dup is not None:
                raise ValueError(
                    f"duplicate fire point: the same {type(fault).__name__} "
                    f"instance is planned at chunks {dup} and {idx}; "
                    f"each boundary needs its own fault instance "
                    f"(faults fire once and carry per-firing state)")
            seen[idx] = fault
        for idx, secs in self.straggle.items():
            self._check_index(idx, "straggle")
            s = float(secs)
            if not s >= 0.0 or s != s or s == float("inf"):
                raise ValueError(
                    f"straggle seconds at chunk {idx} must be finite "
                    f"and >= 0, got {secs!r}")

    @staticmethod
    def _check_index(idx, where: str) -> None:
        if isinstance(idx, bool) or not isinstance(idx, int):
            raise ValueError(
                f"{where} keys must be chunk indices (int), got "
                f"{idx!r} ({type(idx).__name__})")
        if idx < 0:
            raise ValueError(
                f"{where} keys must be >= 0 (chunk indices), got {idx}")

    def at_chunk(self, idx: int) -> None:
        """Raise the planned fault for boundary ``idx`` (once)."""
        fault = self.faults.get(idx)
        if fault is not None and ("fault", idx) not in self._done:
            self._done.add(("fault", idx))
            self.fired.append(f"chunk {idx}: {fault}")
            raise fault

    def straggle_seconds(self, idx: int) -> float:
        """Simulated extra wall seconds for chunk ``idx`` (once)."""
        extra = self.straggle.get(idx, 0.0)
        if extra and ("straggle", idx) not in self._done:
            self._done.add(("straggle", idx))
            self.fired.append(f"chunk {idx}: straggled +{extra:.3f}s")
            return float(extra)
        return 0.0

    @property
    def exhausted(self) -> bool:
        """True once every planned fault and straggle has fired."""
        return len(self._done) == len(self.faults) + \
            sum(1 for v in self.straggle.values() if v)
