"""Fault-tolerant, elastic runtime: the training-loop supervisor
(:mod:`~repro.runtime.fault`), elastic re-meshing
(:mod:`~repro.runtime.elastic`), deterministic fault injection
(:mod:`~repro.runtime.inject`), the persistent schedule cache
(:mod:`~repro.runtime.schedule_cache`), the resilient sweep server
(:mod:`~repro.runtime.resilient_sweep`) and the request-serving
daemon (:mod:`~repro.runtime.serving`)."""
from . import elastic, inject, schedule_cache, serving
from .fault import (FaultConfig, FaultTolerantRunner, StepStats,
                    StragglerAbort, backoff_delay, supervise)
from .inject import (DeviceLoss, FaultPlan, Preemption, SimulatedFault,
                     SimulatedOOM)
from .resilient_sweep import (ResilienceConfig, SweepReport,
                              resilient_sweep_arrivals,
                              resilient_sweep_schedules,
                              resilient_sweep_workloads,
                              resilient_tune_barrier)
from .serving import (ServerClosed, ServerConfig, ServerOverloaded,
                      ServerStats, TuneRequest, TuneResponse,
                      TuningServer)

__all__ = ["DeviceLoss", "FaultConfig", "FaultPlan",
           "FaultTolerantRunner", "Preemption", "ResilienceConfig",
           "ServerClosed", "ServerConfig", "ServerOverloaded",
           "ServerStats", "SimulatedFault", "SimulatedOOM", "StepStats",
           "StragglerAbort", "SweepReport", "TuneRequest",
           "TuneResponse", "TuningServer", "backoff_delay", "elastic",
           "inject", "resilient_sweep_arrivals",
           "resilient_sweep_schedules", "resilient_sweep_workloads",
           "resilient_tune_barrier", "schedule_cache", "serving",
           "supervise"]
