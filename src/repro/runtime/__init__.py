"""Fault-tolerant, elastic runtime."""
from . import elastic
from .fault import (FaultConfig, FaultTolerantRunner, StepStats,
                    StragglerAbort, supervise)

__all__ = ["FaultConfig", "FaultTolerantRunner", "StepStats",
           "StragglerAbort", "elastic", "supervise"]
