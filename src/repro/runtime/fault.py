"""Fault-tolerant training runtime.

Production loop for thousands of nodes, CPU-testable in miniature:

* **checkpoint/restart** — periodic atomic checkpoints
  (checkpoint/ckpt.py); on any failure the supervisor restarts the loop,
  which resumes from ``latest_step`` (the data pipeline is a pure
  function of step, so no loader state needs recovery).
* **straggler mitigation** — per-step wall-time watchdog: steps slower
  than ``straggler_factor`` x the running median are counted; after
  ``max_stragglers`` consecutive slow steps the runner raises
  ``StragglerAbort`` so the supervisor can reschedule the job away from
  the slow host (the paper's arrival-scatter insight: one late PE
  stalls the whole barrier).
* **elastic re-meshing** — on restart the runner rebuilds its mesh from
  the devices that are actually alive; parameters re-shard from the
  checkpoint automatically because shardings are derived from the mesh
  at build time (``elastic.py``).
"""
from __future__ import annotations

import dataclasses
import random
import statistics
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

from .. import checkpoint


class StragglerAbort(RuntimeError):
    """Raised when this worker is persistently slower than its peers."""


@dataclasses.dataclass
class FaultConfig:
    ckpt_dir: str = "runs/ckpt"
    ckpt_every: int = 50
    keep: int = 3
    straggler_factor: float = 3.0
    max_stragglers: int = 5
    max_restarts: int = 3
    # Restart backoff: attempt k sleeps ~ base * 2**k, jittered by a
    # capped deterministic fraction, never above ``backoff_cap`` —
    # immediate hot-loop restarts hammer the scheduler the same way
    # simultaneous barrier arrivals hammer a counter bank.
    backoff_base: float = 0.1
    backoff_cap: float = 5.0
    backoff_jitter: float = 0.25


def backoff_delay(attempt: int, *, base: float, cap: float,
                  jitter: float) -> float:
    """Exponential backoff with a capped, DETERMINISTIC jitter: attempt
    ``k`` waits ``min(cap, base * 2**k)`` stretched by a pseudo-random
    fraction in ``[0, min(jitter, 1)]`` seeded on ``k`` — repeatable in
    tests, desynchronized across attempts, and never above ``cap``."""
    raw = min(cap, base * (2.0 ** attempt))
    frac = random.Random(attempt).uniform(0.0, max(0.0, min(jitter, 1.0)))
    return min(cap, raw * (1.0 + frac))


@dataclasses.dataclass
class StepStats:
    step: int
    seconds: float
    metrics: Dict[str, float]


class FaultTolerantRunner:
    """Drives (state, batch) -> state' steps with checkpointing, a
    straggler watchdog and restart-from-checkpoint semantics."""

    def __init__(self, cfg: FaultConfig, *,
                 step_fn: Callable[[Any, Any], tuple],
                 batch_fn: Callable[[int], Any],
                 state_template: Any):
        self.cfg = cfg
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.template = state_template
        self.history: List[StepStats] = []
        self._durations: List[float] = []
        self._slow = 0

    # -- persistence ----------------------------------------------------
    def resume_step(self) -> int:
        latest = checkpoint.latest_step(self.cfg.ckpt_dir)
        return 0 if latest is None else latest + 1

    def load_state(self) -> Any:
        latest = checkpoint.latest_step(self.cfg.ckpt_dir)
        if latest is None:
            return self.template
        state, _ = checkpoint.restore(self.cfg.ckpt_dir, self.template,
                                      step=latest)
        return state

    # -- watchdog ---------------------------------------------------------
    def _watch(self, seconds: float) -> None:
        self._durations.append(seconds)
        if len(self._durations) < 8:
            return
        med = statistics.median(self._durations[-50:])
        if seconds > self.cfg.straggler_factor * med:
            self._slow += 1
            if self._slow >= self.cfg.max_stragglers:
                raise StragglerAbort(
                    f"{self._slow} consecutive steps "
                    f">{self.cfg.straggler_factor}x median ({med:.3f}s)")
        else:
            self._slow = 0

    # -- main loop --------------------------------------------------------
    def run(self, n_steps: int, *, state: Optional[Any] = None,
            on_step: Optional[Callable[[StepStats], None]] = None) -> Any:
        state = self.load_state() if state is None else state
        start = self.resume_step()
        for step in range(start, n_steps):
            t0 = time.perf_counter()
            batch = self.batch_fn(step)
            state, metrics = self.step_fn(state, batch)
            dt = time.perf_counter() - t0
            stats = StepStats(step, dt, {k: float(v)
                                         for k, v in metrics.items()})
            self.history.append(stats)
            if on_step:
                on_step(stats)
            self._watch(dt)
            if (step + 1) % self.cfg.ckpt_every == 0 or step + 1 == n_steps:
                checkpoint.save(self.cfg.ckpt_dir, step, state)
                checkpoint.prune(self.cfg.ckpt_dir, self.cfg.keep)
        return state


def supervise(make_runner: Callable[[], FaultTolerantRunner],
              n_steps: int, cfg: FaultConfig, *,
              sleep: Callable[[float], None] = time.sleep) -> Any:
    """Restart-on-failure supervisor: rebuilds the runner (and hence the
    mesh — elastic re-meshing) after every fault, up to max_restarts.

    Restart ``k`` first sleeps :func:`backoff_delay`(k-1) — exponential
    with capped jitter, never a hot loop — and the failed attempt's
    ``history`` is carried into the fresh runner, so the step record of
    a supervised run is continuous across faults instead of silently
    resetting.  ``sleep`` is injectable for tests."""
    last_exc: Optional[BaseException] = None
    carried: List[StepStats] = []
    for attempt in range(cfg.max_restarts + 1):
        if attempt:
            sleep(backoff_delay(attempt - 1, base=cfg.backoff_base,
                                cap=cfg.backoff_cap,
                                jitter=cfg.backoff_jitter))
        runner = make_runner()
        runner.history.extend(carried)
        try:
            return runner.run(n_steps)
        except StragglerAbort as e:
            last_exc = e
            carried = list(runner.history)
            continue          # reschedule: new runner, resumes from ckpt
        except Exception as e:  # noqa: BLE001 — any node fault
            last_exc = e
            carried = list(runner.history)
            continue
    raise RuntimeError(
        f"giving up after {cfg.max_restarts} restarts") from last_exc
