"""TeraPool machine model.

The paper's cluster: 1024 Snitch RISC-V PEs tightly coupled to a 4 MiB
multi-banked shared L1.  Hierarchy: 8 PEs / Tile, 16 Tiles / Group,
8 Groups / cluster; banking factor 4 (4096 banks).  Access latency to any
bank is bounded: 1 cycle within the Tile, <3 cycles within the Group,
<5 cycles across Groups.  Banks are single-ported: concurrent atomics to
the same bank serialize at 1 op/cycle.

All timing constants live in :class:`TeraPoolConfig` so the simulator can
be re-calibrated; the defaults reproduce the paper's headline numbers
(see tests/test_barrier_sim.py and EXPERIMENTS.md §Repro).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TeraPoolConfig:
    """Timing/topology model of the TeraPool cluster."""

    n_pes: int = 1024
    pes_per_tile: int = 8
    tiles_per_group: int = 16
    n_groups: int = 8
    banking_factor: int = 4

    # Memory access latency (cycles) by locality class.
    lat_tile: int = 1     # PE -> bank in the same Tile
    lat_group: int = 3    # PE -> bank in the same Group
    lat_cluster: int = 5  # PE -> bank in another Group

    # Single-ported banks: one atomic serviced per cycle.
    bank_service_cycles: int = 1

    # Software overhead of one barrier level: address computation, the
    # amo.add issue slot, the compare/branch on the fetched value and the
    # counter-reset store of the last arriver (re-initialization is folded
    # into the arrival phase, Sec. 3).
    instr_per_level: int = 20

    # Notification phase: write to the memory-mapped wakeup register
    # (AXI, cluster-level latency), wakeup-unit trigger fan-out, and the
    # WFI resume cost of a sleeping Snitch core.
    wakeup_write: int = 5
    wakeup_trigger: int = 2
    wfi_resume: int = 8

    # Hardware event unit (Glaser et al., arXiv 2004.06662: a dedicated
    # synchronization/event unit next to the cores).  A PE signals its
    # arrival with one store to the unit's trigger register
    # (``hw_entry_instr`` cycles of software); the unit's combinational
    # aggregation tree then resolves each stage in ``hw_level_cycles``
    # — no shared-counter atomics, no per-level software path.
    hw_entry_instr: int = 2
    hw_level_cycles: int = 1

    @property
    def pes_per_group(self) -> int:
        return self.pes_per_tile * self.tiles_per_group  # 128

    @property
    def n_banks(self) -> int:
        return self.n_pes * self.banking_factor

    @property
    def banks_per_tile(self) -> int:
        return self.pes_per_tile * self.banking_factor   # 32

    @property
    def banks_per_group(self) -> int:
        return self.pes_per_group * self.banking_factor  # 512

    @property
    def wakeup_cycles(self) -> int:
        """Full notification cost: register write -> trigger -> resume."""
        return self.wakeup_write + self.wakeup_trigger + self.wfi_resume

    def access_latency(self, span: int) -> int:
        """Legacy span heuristic: latency for a PE to reach a counter
        placed local to a *contiguous* block of ``span`` PEs (the paper
        places leaf counters on contiguous PE indices, Sec. 5).

        .. deprecated::
            Counter latency is now derived from an explicit counter ->
            bank mapping (:mod:`repro.core.placement`), which models
            *where* a counter lives instead of assuming it sits inside
            its span.  This method is retained as the documented
            fallback used when no :class:`~repro.core.placement.
            CounterPlacement` is given; the paper-style ``leaf_local``
            strategy reproduces it bit-for-bit
            (tests/test_placement.py).
        """
        if span <= self.pes_per_tile:
            return self.lat_tile
        if span <= self.pes_per_group:
            return self.lat_group
        return self.lat_cluster

    def span_bank_latency(self, pe_lo: int, span: int, bank: int) -> int:
        """Worst-accessor latency for the contiguous PE block
        ``[pe_lo, pe_lo + span)`` to reach ``bank``.

        The locality class is decided by the *farthest* accessing PE —
        consistent with the span heuristic, which charges a whole level
        the class of its span.  A bank inside the accessors' common
        Tile costs ``lat_tile``; inside their common Group,
        ``lat_group``; anything else is a cluster-class access.
        """
        pe_hi = pe_lo + span - 1
        if (pe_lo // self.pes_per_tile == pe_hi // self.pes_per_tile
                == bank // self.banks_per_tile):
            return self.lat_tile
        if (pe_lo // self.pes_per_group == pe_hi // self.pes_per_group
                == bank // self.banks_per_group):
            return self.lat_group
        return self.lat_cluster

    def pe_bank_latency(self, pe: int, bank: int) -> int:
        """Latency for one PE to reach one bank (locality-class model)."""
        return self.span_bank_latency(pe, 1, bank)

    def hw_stage_latency(self, span: int) -> int:
        """Cycles one aggregation stage of the hardware event unit takes
        to resolve once its last input signal is present.  Inside a
        cluster every stage is combinational (``hw_level_cycles``)
        regardless of span — the unit sits next to the cores, signals
        are dedicated wires, not L1 accesses."""
        return self.hw_level_cycles


@dataclasses.dataclass(frozen=True)
class MultiClusterConfig(TeraPoolConfig):
    """TeraPool-of-TeraPools: ``n_clusters`` TeraPool clusters behind an
    inter-cluster interconnect (the scale-out direction of Riedel et
    al., arXiv 2507.05012, and the MemPool line).

    ``n_pes`` is the TOTAL PE count across all clusters; PEs and banks
    keep global contiguous indices, so cluster ``c`` owns PEs
    ``[c * pes_per_cluster, (c+1) * pes_per_cluster)`` and the matching
    bank block.  Inside one cluster the Tile/Group locality classes of
    :class:`TeraPoolConfig` apply unchanged (the per-cluster structure
    may be asymmetric or non-power-of-two, e.g. a 768-PE cluster with
    12 Tiles per Group); any access that crosses a cluster boundary —
    the farthest accessor of a counter, or the counter's bank, living
    in a different cluster — pays the flat remote tier ``lat_remote``
    (AXI hop + remote L1 arbitration, ~5x the intra-cluster worst
    case)."""

    n_clusters: int = 4
    lat_remote: int = 25  # PE -> bank in another cluster

    def __post_init__(self):
        if self.n_clusters < 1:
            raise ValueError(f"need >= 1 cluster, got {self.n_clusters}")
        if self.n_pes % self.n_clusters != 0:
            raise ValueError(
                f"{self.n_pes} PEs do not split into {self.n_clusters} "
                f"equal clusters")

    @property
    def pes_per_cluster(self) -> int:
        return self.n_pes // self.n_clusters

    @property
    def banks_per_cluster(self) -> int:
        return self.pes_per_cluster * self.banking_factor

    def access_latency(self, span: int) -> int:
        """Span heuristic with the remote tier on top: a counter whose
        contiguous span crosses a cluster boundary is remote-class."""
        if span > self.pes_per_cluster:
            return self.lat_remote
        return super().access_latency(span)

    def span_bank_latency(self, pe_lo: int, span: int, bank: int) -> int:
        """Worst-accessor latency with inter-cluster placement classes:
        remote whenever the accessor block spans two clusters or the
        bank lives in a different cluster than the accessors."""
        pe_hi = pe_lo + span - 1
        if not (pe_lo // self.pes_per_cluster
                == pe_hi // self.pes_per_cluster
                == bank // self.banks_per_cluster):
            return self.lat_remote
        return super().span_bank_latency(pe_lo, span, bank)

    def hw_stage_latency(self, span: int) -> int:
        """An aggregation stage whose span crosses a cluster boundary
        combines per-cluster event units over the inter-cluster
        interconnect: it pays the remote tier, not a wire delay."""
        if span > self.pes_per_cluster:
            return self.lat_remote
        return super().hw_stage_latency(span)


def multi_cluster(cluster: TeraPoolConfig = None, n_clusters: int = 4,
                  lat_remote: int = 25) -> MultiClusterConfig:
    """``n_clusters`` copies of ``cluster`` (default: the paper's
    1024-PE TeraPool) as one :class:`MultiClusterConfig`: per-cluster
    timing/structure fields carry over, ``n_pes`` becomes the total."""
    cluster = cluster if cluster is not None else DEFAULT
    fields = {f.name: getattr(cluster, f.name)
              for f in dataclasses.fields(TeraPoolConfig)}
    fields["n_pes"] = cluster.n_pes * n_clusters
    return MultiClusterConfig(**fields, n_clusters=n_clusters,
                              lat_remote=lat_remote)


DEFAULT = TeraPoolConfig()
