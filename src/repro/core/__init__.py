"""The paper's primary contribution, in JAX.

Two coupled layers (DESIGN.md §2):

* **Faithful reproduction** — a cycle-level simulator of TeraPool barrier
  synchronization (:mod:`topology`, :mod:`barrier`, :mod:`barrier_sim`),
  the kernel arrival-time models (:mod:`workloads`) and the full 5G
  OFDM + beamforming application (:mod:`fiveg`).
* **TPU transplant** — radix-tunable hierarchical collective schedules
  and partial synchronization for pod-scale training/serving
  (:mod:`collectives`).
"""
from . import barrier, barrier_sim, collectives, fiveg, topology, workloads
from .barrier import (BarrierSchedule, all_radices, central_counter,
                      kary_tree, partial_barrier)
from .barrier_sim import (BarrierResult, mean_span_cycles, overhead_fraction,
                          simulate, simulate_batch, uniform_arrivals)
from .collectives import (FLAT, HIERARCHICAL, SyncConfig, gather_param,
                          make_factored_mesh, partial_psum, shard_slice,
                          sync_gradient, tree_psum)
from .topology import DEFAULT, TeraPoolConfig

__all__ = [
    "BarrierResult", "BarrierSchedule", "DEFAULT", "FLAT", "HIERARCHICAL",
    "SyncConfig", "TeraPoolConfig", "all_radices", "barrier", "barrier_sim",
    "central_counter", "collectives", "fiveg", "gather_param", "kary_tree",
    "make_factored_mesh", "mean_span_cycles", "overhead_fraction",
    "partial_barrier", "partial_psum", "shard_slice", "simulate",
    "simulate_batch", "sync_gradient", "topology", "tree_psum",
    "uniform_arrivals", "workloads",
]
