"""The paper's primary contribution, in JAX.

Two coupled layers (DESIGN.md §2):

* **Faithful reproduction** — a cycle-level simulator of TeraPool barrier
  synchronization (:mod:`topology`, :mod:`barrier`, :mod:`barrier_sim`),
  bank-aware counter placement (:mod:`placement`), per-barrier energy
  accounting and the hardware event-unit primitive (:mod:`energy`,
  :func:`barrier.hw_event_unit`), one-compile design-space sweeps and
  the exhaustive mixed-radix x placement tuner with latency x energy
  Pareto selection (:mod:`sweep`, :mod:`tuning`), the kernel
  arrival-time models (:mod:`workloads`) and the full 5G OFDM +
  beamforming application (:mod:`fiveg`).
* **TPU transplant** — radix-tunable hierarchical collective schedules
  and partial synchronization for pod-scale training/serving
  (:mod:`collectives`).
"""
from . import (barrier, barrier_sim, collectives, energy, fiveg, placement,
               sweep, topology, tuning, workloads)
from .barrier import (BarrierSchedule, LevelTable, all_radices,
                      central_counter, compose, counter_width, describe,
                      hw_event_unit, kary_tree, level_table,
                      mixed_radix_tree, partial_barrier, schedule_name,
                      stack_tables)
from .energy import DEFAULT_ENERGY, EnergyModel, energy_reference
from .barrier_sim import (BarrierResult, mean_span_cycles, overhead_fraction,
                          simulate, simulate_reference, simulate_table,
                          uniform_arrivals)
from .collectives import (FLAT, HIERARCHICAL, SyncConfig, gather_param,
                          make_factored_mesh, partial_psum, shard_slice,
                          sync_gradient, tree_psum)
from .placement import (STRATEGIES, CounterPlacement, all_placements,
                        derive_latencies, explicit_placement, place_counters,
                        simulate_placed_reference)
from .sweep import (ArrivalSweepResult, SweepResult, best_radix_per_delay,
                    radix_tables, simulate_radices, simulate_schedules,
                    sweep_arrivals, sweep_barrier, sweep_schedules)
from .topology import DEFAULT, TeraPoolConfig
from .tuning import (ParetoPoint, TunedPoint, WorkloadPoint, all_schedules,
                     best_per_delay, best_per_kernel, best_placed_schedule,
                     best_schedule, enumerate_compositions,
                     hierarchy_compositions, pareto_front, pareto_schedules,
                     tune_barrier, tune_for_arrivals, tune_for_workload,
                     tuned_for_workload, sweep_workloads)
from .workloads import ARRIVAL_KERNELS, FIG6_KERNELS, arrival_batch

__all__ = [
    "ARRIVAL_KERNELS", "ArrivalSweepResult", "BarrierResult",
    "BarrierSchedule", "CounterPlacement", "DEFAULT", "DEFAULT_ENERGY",
    "EnergyModel", "FIG6_KERNELS",
    "FLAT", "HIERARCHICAL", "LevelTable", "ParetoPoint", "STRATEGIES",
    "SweepResult",
    "SyncConfig", "TeraPoolConfig", "TunedPoint", "WorkloadPoint",
    "all_placements", "all_radices", "all_schedules", "arrival_batch",
    "barrier", "barrier_sim", "best_per_delay", "best_per_kernel",
    "best_placed_schedule", "best_radix_per_delay",
    "best_schedule", "central_counter", "collectives", "compose",
    "counter_width", "derive_latencies", "describe", "energy",
    "energy_reference",
    "enumerate_compositions", "explicit_placement", "fiveg",
    "gather_param", "hierarchy_compositions", "hw_event_unit",
    "kary_tree", "level_table",
    "make_factored_mesh", "mean_span_cycles", "mixed_radix_tree",
    "overhead_fraction", "pareto_front", "pareto_schedules",
    "partial_barrier",
    "partial_psum", "place_counters", "placement", "radix_tables",
    "schedule_name", "shard_slice", "simulate", "simulate_placed_reference",
    "simulate_radices", "simulate_schedules", "simulate_reference",
    "simulate_table", "stack_tables", "sweep", "sweep_arrivals",
    "sweep_barrier", "sweep_schedules", "sweep_workloads", "sync_gradient",
    "topology", "tree_psum", "tune_barrier", "tune_for_arrivals",
    "tune_for_workload", "tuned_for_workload", "tuning",
    "uniform_arrivals", "workloads",
]
