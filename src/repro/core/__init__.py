"""The paper's primary contribution, in JAX.

Two coupled layers (DESIGN.md §2):

* **Faithful reproduction** — a cycle-level simulator of TeraPool barrier
  synchronization (:mod:`topology`, :mod:`barrier`, :mod:`barrier_sim`),
  the kernel arrival-time models (:mod:`workloads`) and the full 5G
  OFDM + beamforming application (:mod:`fiveg`).
* **TPU transplant** — radix-tunable hierarchical collective schedules
  and partial synchronization for pod-scale training/serving
  (:mod:`collectives`).
"""
from . import (barrier, barrier_sim, collectives, fiveg, sweep, topology,
               workloads)
from .barrier import (BarrierSchedule, LevelTable, all_radices,
                      central_counter, kary_tree, level_table,
                      partial_barrier, stack_tables)
from .barrier_sim import (BarrierResult, mean_span_cycles, overhead_fraction,
                          simulate, simulate_batch, simulate_reference,
                          simulate_table, uniform_arrivals)
from .collectives import (FLAT, HIERARCHICAL, SyncConfig, gather_param,
                          make_factored_mesh, partial_psum, shard_slice,
                          sync_gradient, tree_psum)
from .sweep import (SweepResult, best_radix_per_delay, radix_tables,
                    simulate_radices, sweep_barrier)
from .topology import DEFAULT, TeraPoolConfig

__all__ = [
    "BarrierResult", "BarrierSchedule", "DEFAULT", "FLAT", "HIERARCHICAL",
    "LevelTable", "SweepResult", "SyncConfig", "TeraPoolConfig",
    "all_radices", "barrier", "barrier_sim", "best_radix_per_delay",
    "central_counter", "collectives", "fiveg", "gather_param", "kary_tree",
    "level_table", "make_factored_mesh", "mean_span_cycles",
    "overhead_fraction", "partial_barrier", "partial_psum", "radix_tables",
    "shard_slice", "simulate", "simulate_batch", "simulate_radices",
    "simulate_reference", "simulate_table", "stack_tables", "sweep",
    "sweep_barrier", "sync_gradient", "topology", "tree_psum",
    "uniform_arrivals", "workloads",
]
