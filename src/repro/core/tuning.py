"""Exhaustive, one-compile tuning over the mixed-radix schedule space.

The paper's headline 1.6x comes from *fine-tuning* the synchronization
tree to the machine hierarchy (Sec. 5): the best schedule for TeraPool
is often NOT a uniform radix but a composition matched to the 8/16/8
Tile/Group/Cluster structure.  This module opens that full design
space:

* :func:`enumerate_compositions` — every way to split ``log2(N)`` tree
  depth into power-of-two level sizes: ``2**(log2(N)-1)`` schedules
  (512 at N=1024), a strict superset of every uniform radix.
* :func:`hierarchy_compositions` — the hierarchy-aware pruned search:
  only compositions whose level spans land on Tile/Group/cluster
  boundaries, where counters never straddle a locality class
  (128 schedules at N=1024).
* :func:`tune_barrier` — the exhaustive tuner: every composition x
  placement x delay x trial through the single compiled scanned core
  of :mod:`repro.core.sweep` — one compile for the whole design space.
  The ``placements`` axis crosses each composition with the named
  counter-placement strategies of :mod:`repro.core.placement`, making
  WHERE counters live a tuned knob next to the tree shape.
* :func:`best_per_delay` / :func:`pareto_schedules` — selection: the
  argmin (schedule, placement) at each delay, and the schedules not
  dominated at every delay simultaneously.
* :func:`best_placed_schedule` — the jointly tuned (schedule,
  placement) pair for one arrival scatter (the 5G ``sync="placed"``
  mode consumes this).

Because the uniform radices (and the paper's leaf-local placement) are
a subset of the enumeration, the tuned best can only match or beat the
best uniform radix — the acceptance bar of tests/test_tuning.py and
tests/test_placement.py.
"""
from __future__ import annotations

import math
from typing import List, NamedTuple, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from . import barrier, placement as placement_mod, sweep
from .barrier import BarrierSchedule
from .placement import CounterPlacement
from .topology import DEFAULT, TeraPoolConfig


def enumerate_compositions(n_pes: int | None = None,
                           cfg: TeraPoolConfig = DEFAULT
                           ) -> List[Tuple[int, ...]]:
    """All compositions of ``log2(N)`` into power-of-two level sizes,
    leaf level first, in lexicographic order of the exponent parts.

    ``2**(log2(N) - 1)`` compositions; every :func:`~repro.core.barrier.
    kary_tree` shape (first level adapted, uniform tail) appears among
    them, as does the central counter ``(N,)``.
    """
    n = int(n_pes if n_pes is not None else cfg.n_pes)
    barrier._check_pow2(n, "n_pes")
    m = int(math.log2(n))

    def parts(remaining: int):
        if remaining == 0:
            yield ()
            return
        for p in range(1, remaining + 1):
            for rest in parts(remaining - p):
                yield (1 << p,) + rest

    return list(parts(m))


def hierarchy_compositions(n_pes: int | None = None,
                           cfg: TeraPoolConfig = DEFAULT
                           ) -> List[Tuple[int, ...]]:
    """The hierarchy-aware pruned search space: compositions whose
    cumulative spans include every Tile/Group boundary inside ``N``, so
    no level's counters straddle a locality class.  The product of the
    per-segment compositions — 4 x 8 x 4 = 128 schedules for the full
    8/16/8 cluster versus 512 exhaustive."""
    n = int(n_pes if n_pes is not None else cfg.n_pes)
    barrier._check_pow2(n, "n_pes")
    # Segment factors up the hierarchy, clipped to n.
    t = min(n, cfg.pes_per_tile)
    g = min(n // t, cfg.tiles_per_group)
    c = n // (t * g)
    out: List[Tuple[int, ...]] = []
    segs = [s for s in (t, g, c) if s > 1]
    if not segs:
        return [(n,)] if n > 1 else []

    def seg_parts(size: int):
        return enumerate_compositions(size, cfg) if size > 1 else [()]

    def product(i: int):
        if i == len(segs):
            yield ()
            return
        for head in seg_parts(segs[i]):
            for rest in product(i + 1):
                yield head + rest

    for comp in product(0):
        out.append(comp)
    return out


def all_schedules(n_pes: int | None = None,
                  cfg: TeraPoolConfig = DEFAULT, *,
                  prune: str = "none",
                  partial: bool = False) -> List[BarrierSchedule]:
    """Materialize the search space as schedules.  ``prune`` in
    {"none", "hierarchy"} selects exhaustive vs hierarchy-aligned."""
    if prune == "none":
        comps = enumerate_compositions(n_pes, cfg)
    elif prune == "hierarchy":
        comps = hierarchy_compositions(n_pes, cfg)
    else:
        raise ValueError(f"unknown prune mode {prune!r}")
    return [barrier.mixed_radix_tree(c, cfg=cfg, partial=partial)
            for c in comps]


def tune_barrier(key, n_pes: int | None = None,
                 delays: Sequence[float] = (0.0, 128.0, 512.0, 2048.0),
                 n_trials: int = 16, cfg: TeraPoolConfig = DEFAULT, *,
                 prune: str = "none",
                 schedules: Sequence[BarrierSchedule] | None = None,
                 placements: Sequence[str] | None = None
                 ) -> sweep.SweepResult:
    """Sweep the full mixed-radix design space in ONE compiled call.

    Every composition shares the padded level-table shape, so the whole
    composition x delay x trial grid reuses the single compiled scanned
    core (the same program the uniform-radix Fig. 4 sweep compiles).
    Pass ``schedules`` to tune over an explicit candidate list instead
    of the enumeration.

    ``placements`` — a sequence of strategy names from
    :data:`repro.core.placement.STRATEGIES` — adds the counter
    placement axis: the stack becomes the cross product composition x
    strategy (the result's ``schedules``/``placements`` tuples align
    entry-for-entry), still through the single compiled core.  ``None``
    keeps the placement-free legacy sweep.
    """
    if schedules is None:
        schedules = all_schedules(n_pes, cfg, prune=prune)
    if placements is None:
        return sweep.sweep_schedules(key, schedules, delays, n_trials, cfg)
    for strat in placements:
        if not isinstance(strat, str):
            raise TypeError(
                "placements must be strategy names; pass explicit "
                "CounterPlacements through sweep.sweep_schedules")
    scheds: List[BarrierSchedule] = []
    placs: List[CounterPlacement] = []
    for strat in placements:
        for s in schedules:
            scheds.append(s)
            placs.append(placement_mod.place_counters(s, strat, cfg))
    return sweep.sweep_schedules(key, scheds, delays, n_trials, cfg,
                                 placements=placs)


class TunedPoint(NamedTuple):
    """The winning schedule (+ placement) at one arrival scatter."""

    delay: float
    schedule: BarrierSchedule
    mean_span: float              # its Fig. 4a metric
    uniform_schedule: BarrierSchedule   # best uniform radix at this delay
    uniform_span: float
    placement: object = None      # CounterPlacement | None of the winner


def _is_baseline(plc) -> bool:
    """Placements equivalent to the paper's model (span-heuristic
    fallback or explicit leaf-local) qualify as the uniform baseline."""
    return plc is None or plc.strategy == "leaf_local"


def best_per_delay(res: sweep.SweepResult) -> List[TunedPoint]:
    """The argmin-span (schedule, placement) at each delay, paired with
    the best UNIFORM radix under the paper's leaf-local placement at
    that delay (the Fig. 4a baseline)."""
    spans = jnp.mean(res.span_cycles, axis=-1)          # (S, D)
    placs = res.placements or (None,) * len(res.schedules)
    uniform = [i for i, s in enumerate(res.schedules)
               if s.radix and _is_baseline(placs[i])]
    if not uniform:
        raise ValueError(
            "schedule stack contains no baseline-placed uniform radix")
    out = []
    for j, delay in enumerate(res.delays.tolist()):
        col = spans[:, j]
        i = int(jnp.argmin(col))
        iu = uniform[int(jnp.argmin(col[jnp.asarray(uniform)]))]
        out.append(TunedPoint(
            delay=float(delay), schedule=res.schedules[i],
            mean_span=float(col[i]),
            uniform_schedule=res.schedules[iu],
            uniform_span=float(col[iu]),
            placement=placs[i]))
    return out


def pareto_schedules(res: sweep.SweepResult) -> List[BarrierSchedule]:
    """Schedules on the Pareto front across delays: no other schedule
    is at least as fast at every delay and strictly faster at one."""
    sp = np.asarray(jnp.mean(res.span_cycles, axis=-1))  # (S, D)
    keep = []
    for i in range(sp.shape[0]):
        dominated = np.any(np.all(sp <= sp[i], axis=1)
                           & np.any(sp < sp[i], axis=1))
        if not dominated:
            keep.append(res.schedules[i])
    return keep


def best_schedule(key, n_pes: int | None = None, delay: float = 0.0,
                  n_trials: int = 16, cfg: TeraPoolConfig = DEFAULT, *,
                  prune: str = "none", partial: bool = False
                  ) -> BarrierSchedule:
    """Convenience: the single tuned schedule for one arrival scatter
    (used by the 5G ``sync="tuned"`` modes)."""
    schedules = all_schedules(n_pes, cfg, prune=prune, partial=partial)
    res = tune_barrier(key, n_pes, delays=(delay,), n_trials=n_trials,
                       cfg=cfg, schedules=schedules)
    i = int(jnp.argmin(jnp.mean(res.span_cycles, axis=-1)[:, 0]))
    return schedules[i]


def best_placed_schedule(key, n_pes: int | None = None, delay: float = 0.0,
                         n_trials: int = 16,
                         cfg: TeraPoolConfig = DEFAULT, *,
                         prune: str = "none", partial: bool = False,
                         placements: Sequence[str] = placement_mod.STRATEGIES
                         ) -> Tuple[BarrierSchedule, CounterPlacement]:
    """The jointly tuned (schedule, placement) pair for one arrival
    scatter: composition x strategy through one compiled sweep (used by
    the 5G ``sync="placed"`` mode).  Because leaf-local is in the
    strategy set, the placed winner can only match or beat the
    placement-free tuned schedule on the tuning draws."""
    schedules = all_schedules(n_pes, cfg, prune=prune, partial=partial)
    res = tune_barrier(key, n_pes, delays=(delay,), n_trials=n_trials,
                       cfg=cfg, schedules=schedules, placements=placements)
    i = int(jnp.argmin(jnp.mean(res.span_cycles, axis=-1)[:, 0]))
    return res.schedules[i], res.placements[i]
