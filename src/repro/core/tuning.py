"""Exhaustive, one-compile tuning over the mixed-radix schedule space.

The paper's headline 1.6x comes from *fine-tuning* the synchronization
tree to the machine hierarchy (Sec. 5): the best schedule for TeraPool
is often NOT a uniform radix but a composition matched to the 8/16/8
Tile/Group/Cluster structure.  This module opens that full design
space:

* :func:`enumerate_compositions` — every way to split ``log2(N)`` tree
  depth into power-of-two level sizes: ``2**(log2(N)-1)`` schedules
  (512 at N=1024), a strict superset of every uniform radix.
* :func:`hierarchy_compositions` — the hierarchy-aware pruned search:
  only compositions whose level spans land on Tile/Group/cluster
  boundaries, where counters never straddle a locality class
  (128 schedules at N=1024).
* :func:`multicluster_schedules` — the scale-out space for
  :class:`~repro.core.topology.MultiClusterConfig` machines: every
  intra-cluster composition jointly crossed with every inter-cluster
  tree (4096-16384 PEs through the same one-compile sweep).
* :func:`tune_barrier` — the exhaustive tuner: every composition x
  placement x delay x trial through the single compiled scanned core
  of :mod:`repro.core.sweep` — one compile for the whole design space.
  The ``placements`` axis crosses each composition with the named
  counter-placement strategies of :mod:`repro.core.placement`, making
  WHERE counters live a tuned knob next to the tree shape.
* :func:`best_per_delay` / :func:`pareto_schedules` — selection: the
  argmin (schedule, placement) at each delay, and the schedules not
  dominated at every delay simultaneously — optionally across BOTH the
  cycles and energy objectives (:mod:`repro.core.energy`).
* :func:`pareto_front` — the true 2-D latency x energy front at one
  delay: the non-dominated (schedule, placement) design points, sorted
  fastest-first, exposing the latency/energy budget trade-off.
* :func:`best_placed_schedule` — the jointly tuned (schedule,
  placement) pair for one arrival scatter (the 5G ``sync="placed"``
  mode consumes this).  Both selectors take ``objective=`` ("cycles" |
  "energy" | "edp") to pick the tuning metric.
* :func:`sweep_workloads` / :func:`best_per_kernel` /
  :func:`tune_for_workload` — WORKLOAD-conditioned tuning: the same
  one-compile grid driven by each kernel's *measured* arrival
  distribution (:mod:`repro.core.workloads`) instead of uniform
  scatters, so the winning schedule reflects e.g. ``dotp``'s
  atomic-reduction tail or ``conv2d``'s bimodal border imbalance.
  :func:`tune_for_arrivals` tunes against an explicit arrival matrix
  (the 5G ``sync="workload"`` per-epoch specialization consumes this),
  and :func:`tuned_for_workload` is the lru-cached schedule store
  keyed on (kernel, N, cfg).

Because the uniform radices (and the paper's leaf-local placement) are
a subset of the enumeration, the tuned best can only match or beat the
best uniform radix — the acceptance bar of tests/test_tuning.py and
tests/test_placement.py.
"""
from __future__ import annotations

import functools
import math
from typing import List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import barrier, placement as placement_mod, sweep
from . import workloads as workloads_mod
from .barrier import BarrierSchedule
from .placement import CounterPlacement
from .topology import DEFAULT, TeraPoolConfig


def enumerate_compositions(n_pes: int | None = None,
                           cfg: TeraPoolConfig = DEFAULT
                           ) -> List[Tuple[int, ...]]:
    """All ordered factorizations of ``N`` into level sizes >= 2, leaf
    level first — for power-of-two ``N`` this is exactly the classic
    composition-of-``log2(N)`` space in the same lexicographic order
    (``2**(log2(N) - 1)`` entries), and for non-power-of-two ``N``
    (768-PE clusters, 12-way groups, cluster counts) it is its natural
    generalization.  Every :func:`~repro.core.barrier.kary_tree` shape
    (first level adapted, uniform tail) appears among them, as does the
    central counter ``(N,)``.
    """
    n = int(n_pes if n_pes is not None else cfg.n_pes)
    if n < 2:
        raise ValueError(f"n_pes must be >= 2, got {n}")

    def facts(remaining: int):
        if remaining == 1:
            yield ()
            return
        for f in range(2, remaining + 1):
            if remaining % f:
                continue
            for rest in facts(remaining // f):
                yield (f,) + rest

    return list(facts(n))


def _hier_segments(n: int, cfg: TeraPoolConfig) -> List[int]:
    """Locality-class segment sizes of ``n`` PEs under ``cfg``, leaf
    first: Tile share, Group share, cluster share — topped by the
    cluster count when ``cfg`` is a :class:`~repro.core.topology.
    MultiClusterConfig` and ``n`` spans several clusters.  ``gcd``
    (not ``min``) aligns each segment for non-power-of-two shapes;
    both agree on power-of-two machines."""
    top: List[int] = []
    ppc = getattr(cfg, "pes_per_cluster", n)
    if getattr(cfg, "n_clusters", 1) > 1 and n > ppc and n % ppc == 0:
        top = [n // ppc]
        n = ppc
    t = math.gcd(n, cfg.pes_per_tile)
    g = math.gcd(n // t, cfg.tiles_per_group)
    c = n // (t * g)
    return [s for s in (t, g, c) if s > 1] + top


def hierarchy_compositions(n_pes: int | None = None,
                           cfg: TeraPoolConfig = DEFAULT
                           ) -> List[Tuple[int, ...]]:
    """The hierarchy-aware pruned search space: compositions whose
    cumulative spans include every Tile/Group — and, on a
    multi-cluster machine, cluster — boundary inside ``N``, so no
    level's counters straddle a locality class.  The product of the
    per-segment compositions — 4 x 8 x 4 = 128 schedules for the full
    8/16/8 cluster versus 512 exhaustive."""
    n = int(n_pes if n_pes is not None else cfg.n_pes)
    out: List[Tuple[int, ...]] = []
    segs = _hier_segments(n, cfg)
    if not segs:
        return [(n,)] if n > 1 else []

    def seg_parts(size: int):
        return enumerate_compositions(size, cfg) if size > 1 else [()]

    def product(i: int):
        if i == len(segs):
            yield ()
            return
        for head in seg_parts(segs[i]):
            for rest in product(i + 1):
                yield head + rest

    for comp in product(0):
        out.append(comp)
    return out


def multicluster_compositions(cfg, *,
                              intra: Sequence[Tuple[int, ...]] | None = None,
                              inter: Sequence[Tuple[int, ...]] | None = None
                              ) -> List[Tuple[int, ...]]:
    """The hierarchical multi-cluster search space: every intra-cluster
    composition extended by every inter-cluster tree, leaf first.

    ``intra`` defaults to the hierarchy-pruned per-cluster space
    (:func:`hierarchy_compositions` over ``cfg.pes_per_cluster``) and
    ``inter`` to the full factorization space of ``cfg.n_clusters``
    (:func:`enumerate_compositions`), so the joint sweep tunes the
    inside-the-cluster tree and the cross-cluster reduction together —
    the scale-out analogue of the paper's Sec. 5 fine-tuning.
    """
    if intra is None:
        intra = hierarchy_compositions(cfg.pes_per_cluster, cfg)
    if inter is None:
        inter = (enumerate_compositions(cfg.n_clusters, cfg)
                 if cfg.n_clusters > 1 else [()])
    return [tuple(ic) + tuple(xc) for ic in intra for xc in inter]


def multicluster_schedules(cfg, *,
                           intra: Sequence[Tuple[int, ...]] | None = None,
                           inter: Sequence[Tuple[int, ...]] | None = None,
                           partial: bool = False) -> List[BarrierSchedule]:
    """Materialize :func:`multicluster_compositions` as schedules over
    the full ``cfg.n_pes`` machine (one stacked
    :class:`~repro.core.barrier.LevelTable` shape — the whole space is
    one compile through the sweep entry points).

    Energy folds in automatically: inter-cluster levels carry
    ``cfg.lat_remote`` as their latency, which
    :func:`repro.core.energy.schedule_energy_constants` prices per
    atomic hop — so a remote-cluster counter costs ~5x a Group-local
    one in pJ just as it does in cycles, and the 2-D
    :func:`pareto_front` over this space trades wide low-traffic
    inter-cluster trees against deep low-latency ones."""
    return [barrier.mixed_radix_tree(c, cfg=cfg, partial=partial)
            for c in multicluster_compositions(cfg, intra=intra,
                                               inter=inter)]


def all_schedules(n_pes: int | None = None,
                  cfg: TeraPoolConfig = DEFAULT, *,
                  prune: str = "none",
                  partial: bool = False) -> List[BarrierSchedule]:
    """Materialize the search space as schedules.  ``prune`` in
    {"none", "hierarchy"} selects exhaustive vs hierarchy-aligned."""
    if prune == "none":
        comps = enumerate_compositions(n_pes, cfg)
    elif prune == "hierarchy":
        comps = hierarchy_compositions(n_pes, cfg)
    else:
        raise ValueError(f"unknown prune mode {prune!r}")
    return [barrier.mixed_radix_tree(c, cfg=cfg, partial=partial)
            for c in comps]


def tune_barrier(key, n_pes: int | None = None,
                 delays: Sequence[float] = (0.0, 128.0, 512.0, 2048.0),
                 n_trials: int = 16, cfg: TeraPoolConfig = DEFAULT, *,
                 prune: str = "none",
                 schedules: Sequence[BarrierSchedule] | None = None,
                 placements: Sequence[str] | None = None,
                 core: str | None = None,
                 trial_chunk: int | None = None,
                 shard: bool = True,
                 faults=None) -> sweep.SweepResult:
    """Sweep the full mixed-radix design space in ONE compiled call.

    Every composition shares the padded level-table shape, so the whole
    composition x delay x trial grid reuses the single compiled scanned
    core (the same program the uniform-radix Fig. 4 sweep compiles).
    Pass ``schedules`` to tune over an explicit candidate list instead
    of the enumeration.

    ``placements`` — a sequence of strategy names from
    :data:`repro.core.placement.STRATEGIES` — adds the counter
    placement axis: the stack becomes the cross product composition x
    strategy (the result's ``schedules``/``placements`` tuples align
    entry-for-entry), still through the single compiled core.  ``None``
    keeps the placement-free legacy sweep.

    ``core`` / ``trial_chunk`` / ``shard`` / ``faults`` pass through to
    :func:`repro.core.sweep.sweep_schedules`: simulator-core selection,
    bounded-memory trial chunking (bit-for-bit identical),
    schedule-axis device sharding, and the timeout/quorum
    :class:`~repro.core.barrier.FaultSpec` switching the grid to the
    degradation-tolerant cores (pair it with the robustness
    objectives: ``"p99_cycles"``, ``"worst_cycles"``,
    ``"completion"``).
    """
    if schedules is None:
        schedules = all_schedules(n_pes, cfg, prune=prune)
    scheds, placs = _cross_placements(schedules, placements, cfg)
    return sweep.sweep_schedules(key, scheds, delays, n_trials, cfg,
                                 placements=placs, core=core,
                                 trial_chunk=trial_chunk, shard=shard,
                                 faults=faults)


def _cross_placements(schedules: Sequence[BarrierSchedule],
                      placements: Sequence[str] | None,
                      cfg: TeraPoolConfig
                      ) -> Tuple[Sequence[BarrierSchedule],
                                 Sequence[CounterPlacement] | None]:
    """Cross a schedule stack with named placement strategies into
    aligned (schedules, placements) stacks; ``None`` passes the
    placement-free stack through."""
    if placements is None:
        return tuple(schedules), None
    for strat in placements:
        if not isinstance(strat, str):
            raise TypeError(
                "placements must be strategy names; pass explicit "
                "CounterPlacements through sweep.sweep_schedules")
    scheds: List[BarrierSchedule] = []
    placs: List[CounterPlacement | None] = []
    for strat in placements:
        for s in schedules:
            if s.hw:
                continue   # the event unit has no counters to place
            scheds.append(s)
            placs.append(placement_mod.place_counters(s, strat, cfg))
    # Hardware event-unit schedules join the stack exactly once, with
    # no placement — the strategy axis is meaningless for them.
    for s in schedules:
        if s.hw:
            scheds.append(s)
            placs.append(None)
    return scheds, placs


class TunedPoint(NamedTuple):
    """The winning schedule (+ placement) at one arrival scatter."""

    delay: float
    schedule: BarrierSchedule
    mean_span: float              # its Fig. 4a metric
    uniform_schedule: BarrierSchedule   # best uniform radix at this delay
    uniform_span: float
    placement: object = None      # CounterPlacement | None of the winner


def _is_baseline(plc) -> bool:
    """Placements equivalent to the paper's model (span-heuristic
    fallback or explicit leaf-local) qualify as the uniform baseline."""
    return plc is None or plc.strategy == "leaf_local"


def _uniform_baseline(res) -> Tuple[tuple, List[int]]:
    """The per-point placements of a sweep result plus the indices of
    its baseline-placed uniform-radix schedules (the shared selection
    scaffolding of :func:`best_per_delay` / :func:`best_per_kernel`)."""
    placs = res.placements or (None,) * len(res.schedules)
    uniform = [i for i, s in enumerate(res.schedules)
               if s.radix and _is_baseline(placs[i])]
    if not uniform:
        raise ValueError(
            "schedule stack contains no baseline-placed uniform radix")
    return placs, uniform


def _column_winners(col: jnp.ndarray, uniform: List[int]) -> Tuple[int, int]:
    """(overall argmin, argmin among the uniform baseline) of one span
    column."""
    i = int(jnp.argmin(col))
    iu = uniform[int(jnp.argmin(col[jnp.asarray(uniform)]))]
    return i, iu


def best_per_delay(res: sweep.SweepResult) -> List[TunedPoint]:
    """The argmin-span (schedule, placement) at each delay, paired with
    the best UNIFORM radix under the paper's leaf-local placement at
    that delay (the Fig. 4a baseline)."""
    spans = jnp.mean(res.span_cycles, axis=-1)          # (S, D)
    placs, uniform = _uniform_baseline(res)
    out = []
    for j, delay in enumerate(res.delays.tolist()):
        col = spans[:, j]
        i, iu = _column_winners(col, uniform)
        out.append(TunedPoint(
            delay=float(delay), schedule=res.schedules[i],
            mean_span=float(col[i]),
            uniform_schedule=res.schedules[iu],
            uniform_span=float(col[iu]),
            placement=placs[i]))
    return out


_OBJECTIVE_GRIDS = ("cycles", "energy", "p99_cycles", "worst_cycles",
                    "completion")


def _objective_grid(res, objective: str) -> jnp.ndarray:
    """(S, D) selection metric per objective: mean Fig. 4a span
    (``"cycles"``), mean episode energy in pJ (``"energy"``), or their
    product, the energy-delay product (``"edp"``).

    The robustness objectives tune the TAIL instead of the mean —
    ``"p99_cycles"`` (99th-percentile span over trials; the ``"lower"``
    interpolation keeps it finite whenever <1% of trials hang),
    ``"worst_cycles"`` (max span over trials), and ``"completion"``
    (mean abandoned-PE count, minimized — the completion-rate-maximal
    pick under fault-injected sweeps; identically zero without
    faults)."""
    sp = jnp.mean(res.span_cycles, axis=-1)
    if objective == "cycles":
        return sp
    if objective == "p99_cycles":
        return jnp.percentile(res.span_cycles, 99.0, axis=-1,
                              method="lower")
    if objective == "worst_cycles":
        return jnp.max(res.span_cycles, axis=-1)
    if objective == "completion":
        return jnp.mean(res.abandoned_pes.astype(jnp.float32), axis=-1)
    en = jnp.mean(res.energy, axis=-1)
    if objective == "energy":
        return en
    if objective == "edp":
        return sp * en
    raise ValueError(
        f"unknown objective {objective!r}; choose from "
        f"('cycles', 'energy', 'edp', 'p99_cycles', 'worst_cycles', "
        f"'completion')")


def pareto_schedules(res: sweep.SweepResult,
                     objectives: Sequence[str] = ("cycles",)
                     ) -> List[BarrierSchedule]:
    """Schedules on the Pareto front across delays: no other schedule
    is at least as good in every (delay, objective) column and strictly
    better in one.

    ``objectives`` generalizes the front from best-by-cycles to the
    joint latency x energy trade: with ``("cycles", "energy")`` each
    schedule's point is its mean span AND mean energy at every delay,
    so a schedule survives if nothing beats it across the whole 2-D
    grid simultaneously.  The default reproduces the legacy
    cycles-only front."""
    cols = []
    for obj in objectives:
        if obj not in _OBJECTIVE_GRIDS:
            raise ValueError(
                f"unknown objective {obj!r}; choose from "
                f"{_OBJECTIVE_GRIDS}")
        cols.append(np.asarray(_objective_grid(res, obj)))
    sp = np.concatenate(cols, axis=1)     # (S, D * n_objectives)
    keep = []
    for i in range(sp.shape[0]):
        dominated = np.any(np.all(sp <= sp[i], axis=1)
                           & np.any(sp < sp[i], axis=1))
        if not dominated:
            keep.append(res.schedules[i])
    return keep


class ParetoPoint(NamedTuple):
    """One non-dominated (schedule, placement) design point of the 2-D
    latency x energy front at a single delay/kernel column."""

    schedule: BarrierSchedule
    placement: object             # CounterPlacement | None
    name: str                     # canonical label incl. @strategy
    mean_span: float              # cycles (Fig. 4a metric)
    mean_energy: float            # pJ per episode


def pareto_front(res, column: int = 0) -> List[ParetoPoint]:
    """The true 2-D latency x energy Pareto front at one delay column
    (:class:`~repro.core.sweep.SweepResult`) or kernel column
    (:class:`~repro.core.sweep.ArrivalSweepResult`): every (schedule,
    placement) point no other point beats on BOTH mean span and mean
    energy (with one strict).  Sorted fastest-first, so the first entry
    is the 1-D best-by-cycles winner and the last is the
    energy-minimal design — the curve the tuner exposes to a
    latency/energy budget trade-off."""
    sp = np.asarray(jnp.mean(res.span_cycles, axis=-1))[:, column]
    en = np.asarray(jnp.mean(res.energy, axis=-1))[:, column]
    placs = res.placements or (None,) * len(res.schedules)
    names = res.names
    front = []
    for i in range(sp.shape[0]):
        dominated = np.any((sp <= sp[i]) & (en <= en[i])
                           & ((sp < sp[i]) | (en < en[i])))
        if not dominated:
            front.append(ParetoPoint(
                schedule=res.schedules[i], placement=placs[i],
                name=names[i], mean_span=float(sp[i]),
                mean_energy=float(en[i])))
    return sorted(front, key=lambda p: (p.mean_span, p.mean_energy))


def knee_point(front: Sequence[ParetoPoint]) -> ParetoPoint:
    """The knee of a 2-D latency x energy front: the point closest (in
    min-max-normalized Euclidean distance) to the utopia corner
    ``(span_min, energy_min)``.  This is the balanced pick the 5G
    ``sync="pareto"`` mode and ``objective="pareto"`` serving requests
    use — faster than the energy-minimal end, cheaper than the
    best-by-cycles end, deterministic for a given front."""
    if not front:
        raise ValueError("empty Pareto front")
    if len(front) == 1:
        return front[0]
    sp = np.array([p.mean_span for p in front], np.float64)
    en = np.array([p.mean_energy for p in front], np.float64)
    ns = (sp - sp.min()) / ((sp.max() - sp.min()) or 1.0)
    ne = (en - en.min()) / ((en.max() - en.min()) or 1.0)
    return front[int(np.argmin(np.hypot(ns, ne)))]


class TunedColumn(NamedTuple):
    """Per-kernel-column winner of a batched arrival sweep under one
    request's objective — the unit the serving daemon hands back."""

    schedule: BarrierSchedule
    placement: object             # CounterPlacement | None
    name: str
    mean_span: float
    mean_energy: float


def best_for_arrival_stack(res, objectives) -> List[TunedColumn]:
    """Decompose one batched ``sweep_arrivals`` grid into per-kernel
    winners, each column selected under ITS OWN objective (``"cycles"``,
    ``"energy"``, ``"edp"``, or ``"pareto"`` = knee of the 2-D front).

    This is the batch-composition hook of
    :class:`repro.runtime.serving.TuningServer`: requests with different
    objectives share a single compile/dispatch and are split here.
    ``objectives`` is one string (applied to every column) or a sequence
    with one entry per kernel column."""
    n_cols = len(res.kernels)
    if isinstance(objectives, str):
        objectives = (objectives,) * n_cols
    if len(objectives) != n_cols:
        raise ValueError(
            f"{len(objectives)} objectives for {n_cols} kernel columns")
    sp = np.asarray(jnp.mean(res.span_cycles, axis=-1))
    en = np.asarray(jnp.mean(res.energy, axis=-1))
    placs = res.placements or (None,) * len(res.schedules)
    names = res.names
    out = []
    for j, obj in enumerate(objectives):
        if obj == "pareto":
            p = knee_point(pareto_front(res, column=j))
            out.append(TunedColumn(p.schedule, p.placement, p.name,
                                   p.mean_span, p.mean_energy))
            continue
        i = int(np.argmin(np.asarray(_objective_grid(res, obj))[:, j]))
        out.append(TunedColumn(res.schedules[i], placs[i], names[i],
                               float(sp[i, j]), float(en[i, j])))
    return out


def best_schedule(key, n_pes: int | None = None, delay: float = 0.0,
                  n_trials: int = 16, cfg: TeraPoolConfig = DEFAULT, *,
                  prune: str = "none", partial: bool = False,
                  core: str | None = None,
                  objective: str = "cycles") -> BarrierSchedule:
    """Convenience: the single tuned schedule for one arrival scatter
    (used by the 5G ``sync="tuned"`` modes).  ``objective`` selects the
    tuning metric: ``"cycles"`` (mean span — the legacy behavior),
    ``"energy"`` (mean episode energy) or ``"edp"`` (their product)."""
    schedules = all_schedules(n_pes, cfg, prune=prune, partial=partial)
    res = tune_barrier(key, n_pes, delays=(delay,), n_trials=n_trials,
                       cfg=cfg, schedules=schedules, core=core)
    i = int(jnp.argmin(_objective_grid(res, objective)[:, 0]))
    return schedules[i]


def best_placed_schedule(key, n_pes: int | None = None, delay: float = 0.0,
                         n_trials: int = 16,
                         cfg: TeraPoolConfig = DEFAULT, *,
                         prune: str = "none", partial: bool = False,
                         placements: Sequence[str] = placement_mod.STRATEGIES,
                         core: str | None = None,
                         objective: str = "cycles"
                         ) -> Tuple[BarrierSchedule, CounterPlacement]:
    """The jointly tuned (schedule, placement) pair for one arrival
    scatter: composition x strategy through one compiled sweep (used by
    the 5G ``sync="placed"`` mode).  Because leaf-local is in the
    strategy set, the placed winner can only match or beat the
    placement-free tuned schedule on the tuning draws.  ``objective``
    selects the tuning metric as in :func:`best_schedule`."""
    schedules = all_schedules(n_pes, cfg, prune=prune, partial=partial)
    res = tune_barrier(key, n_pes, delays=(delay,), n_trials=n_trials,
                       cfg=cfg, schedules=schedules, placements=placements,
                       core=core)
    i = int(jnp.argmin(_objective_grid(res, objective)[:, 0]))
    return res.schedules[i], res.placements[i]


# ---------------------------------------------------------------------------
# Workload-conditioned tuning: measured arrival distributions as the
# tuning axis (the Fig. 5/6 kernels + the 5G epochs), not uniform delays.
# ---------------------------------------------------------------------------

class WorkloadPoint(NamedTuple):
    """The winning schedule (+ placement) for one kernel's measured
    arrival distribution."""

    kernel: str
    schedule: BarrierSchedule
    mean_span: float              # its Fig. 4a metric on these arrivals
    uniform_schedule: BarrierSchedule   # best baseline-placed uniform radix
    uniform_span: float
    placement: object = None      # CounterPlacement | None of the winner


def sweep_workloads(key, kernels: Sequence[str] | None = None,
                    n_pes: int | None = None, n_trials: int = 8,
                    cfg: TeraPoolConfig = DEFAULT, *,
                    prune: str = "none",
                    schedules: Sequence[BarrierSchedule] | None = None,
                    placements: Sequence[str] | None = None,
                    core: str | None = None,
                    trial_chunk: int | None = None,
                    shard: bool = True,
                    faults=None,
                    fault_model=None) -> sweep.ArrivalSweepResult:
    """Sweep every kernel's MEASURED arrival distribution across the
    schedule (x placement) stack in one compiled call.

    Each kernel in ``kernels`` (default: the full Fig. 5/6 suite,
    :data:`repro.core.workloads.FIG6_KERNELS`) contributes an
    ``(n_trials, N)`` batch from :func:`repro.core.workloads.
    arrival_batch` under its own key split; the stacked
    kernel x schedule x placement x trial grid then reuses the single
    compiled scanned core via :func:`repro.core.sweep.sweep_arrivals` —
    same one-compile property as the uniform-delay tuner, with
    data-dependent arrivals.

    ``fault_model`` (a :class:`~repro.core.workloads.PEFaultModel`)
    degrades every kernel's batch with per-PE straggles / stalls /
    fail-stops under a key folded off ``key`` — the fault-free draws
    are IDENTICAL to the no-model call, so robustness deltas isolate
    the faults.  Pair any nonzero ``p_fail`` with a finite-timeout or
    sub-1.0-quorum ``faults`` spec (otherwise the plain cores
    propagate the ``+inf`` arrivals into hung episodes)."""
    n = int(n_pes if n_pes is not None else cfg.n_pes)
    if kernels is None:
        kernels = workloads_mod.FIG6_KERNELS
    kernels = tuple(kernels)
    if not kernels:
        raise ValueError("need at least one kernel to sweep")
    keys = jax.random.split(key, len(kernels))
    arrivals = jnp.stack([
        workloads_mod.arrival_batch(k, kernel, (n_trials, n), cfg=cfg)
        for k, kernel in zip(keys, kernels)])
    if fault_model is not None:
        arrivals = workloads_mod.apply_faults(
            jax.random.fold_in(key, 0x0FA17), arrivals, fault_model)
    if schedules is None:
        schedules = all_schedules(n, cfg, prune=prune)
    scheds, placs = _cross_placements(schedules, placements, cfg)
    return sweep.sweep_arrivals(arrivals, scheds, cfg, placements=placs,
                                kernels=kernels, core=core,
                                trial_chunk=trial_chunk, shard=shard,
                                faults=faults)


def best_per_kernel(res: sweep.ArrivalSweepResult) -> List[WorkloadPoint]:
    """The argmin-span (schedule, placement) for each kernel's measured
    arrivals, paired with the best baseline-placed UNIFORM radix on the
    same arrivals (the Fig. 6 per-kernel radix-selection baseline)."""
    spans = jnp.mean(res.span_cycles, axis=-1)          # (S, K)
    placs, uniform = _uniform_baseline(res)
    out = []
    for j, kernel in enumerate(res.kernels):
        col = spans[:, j]
        i, iu = _column_winners(col, uniform)
        out.append(WorkloadPoint(
            kernel=str(kernel), schedule=res.schedules[i],
            mean_span=float(col[i]),
            uniform_schedule=res.schedules[iu],
            uniform_span=float(col[iu]),
            placement=placs[i]))
    return out


def tune_for_workload(key, kernel: str, n_pes: int | None = None,
                      n_trials: int = 8, cfg: TeraPoolConfig = DEFAULT, *,
                      prune: str = "none",
                      placements: Sequence[str] | None = None,
                      core: str | None = None) -> WorkloadPoint:
    """Tune one kernel: its measured arrival batch through the full
    schedule (x placement) stack, argmin by mean span.

    Because the stack is a superset of every uniform radix (and, with
    ``placements``, of every placed point), the returned schedule can
    only match or beat both the best uniform radix AND whatever
    :func:`best_per_delay` selected on uniform scatters, when all are
    evaluated on this kernel's own arrivals — the acceptance bar of
    tests/test_workload_tuning.py."""
    res = sweep_workloads(key, (kernel,), n_pes, n_trials, cfg,
                          prune=prune, placements=placements, core=core)
    return best_per_kernel(res)[0]


def tune_for_arrivals(arrivals, cfg: TeraPoolConfig = DEFAULT, *,
                      prune: str = "none", partial: bool = False,
                      schedules: Sequence[BarrierSchedule] | None = None,
                      placements: Sequence[str] | None = None,
                      core: str | None = None,
                      objective: str = "cycles"
                      ) -> Tuple[BarrierSchedule, CounterPlacement | None,
                                 float]:
    """The winning (schedule, placement, mean_span) for an EXPLICIT
    arrival matrix ``(n_trials, N)`` — e.g. a trace of one 5G epoch, or
    a mixture of epochs stacked along the trial axis.  The 5G
    ``sync="workload"`` mode tunes each of its barriers through this.

    ``objective`` selects the winner: ``"cycles"`` (legacy argmin by
    mean span), ``"energy"``, ``"edp"``, or ``"pareto"`` (knee of the
    2-D latency x energy front).  The returned float is always the
    winner's mean span so callers can report the latency cost of a
    non-cycles pick."""
    arrivals = jnp.asarray(arrivals, jnp.float32)
    if arrivals.ndim == 1:
        arrivals = arrivals[None]
    if arrivals.ndim != 2:
        raise ValueError(
            f"expected an (n_trials, n_pes) arrival matrix, got shape "
            f"{arrivals.shape}")
    n = arrivals.shape[-1]
    if schedules is None:
        schedules = all_schedules(n, cfg, prune=prune, partial=partial)
    scheds, placs = _cross_placements(schedules, placements, cfg)
    res = sweep.sweep_arrivals(arrivals, scheds, cfg, placements=placs,
                               core=core)
    win = best_for_arrival_stack(res, (objective,))[0]
    return win.schedule, win.placement, win.mean_span


# Fixed seed for the workload tuner's arrival draws: tuning is part of
# the schedule construction, deterministic per (kernel, N, cfg).
_WORKLOAD_TUNING_SEED = 65


@functools.lru_cache(maxsize=None)
def tuned_for_workload(kernel: str, n_pes: int | None = None,
                       cfg: TeraPoolConfig = DEFAULT, *,
                       prune: str = "none", n_trials: int = 8,
                       placements: Tuple[str, ...] | None = None
                       ) -> Tuple[BarrierSchedule, CounterPlacement | None]:
    """The two-layer schedule store: the winning (schedule, placement)
    for ``kernel`` at ``(n_pes, cfg)``, tuned once under a fixed seed
    and reused by every later consumer (apps, benchmarks, examples).

    The lru cache is the in-process layer; beneath it sits the
    persistent, checksummed on-disk store of
    :mod:`repro.runtime.schedule_cache` (active when
    ``REPRO_SCHEDULE_CACHE`` is set), so a SECOND PROCESS asking for
    the same ``(kernel, n_pes, cfg)`` performs zero sweep recomputation
    — and a corrupt cache entry is detected and re-tuned, not
    trusted."""
    from ..runtime import schedule_cache
    key = ("tuned_for_workload", kernel, int(n_pes or cfg.n_pes),
           repr(cfg), prune, int(n_trials), placements)
    hit = schedule_cache.load(key)
    if hit is not None:
        return schedule_cache.decode_pair(hit, cfg)
    p = tune_for_workload(jax.random.PRNGKey(_WORKLOAD_TUNING_SEED),
                          kernel, n_pes, n_trials, cfg, prune=prune,
                          placements=placements)
    schedule_cache.store(key, schedule_cache.encode_pair(p.schedule,
                                                         p.placement))
    return p.schedule, p.placement
