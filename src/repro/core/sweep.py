"""One-compile design-space sweeps over barrier radices and arrival
scatters.

The paper's whole result set (Figs. 4-7) is a sweep: barrier radix x
arrival scatter x Monte-Carlo trial.  Because every power-of-two radix
over one cluster shares a padded :class:`~repro.core.barrier.LevelTable`
shape, the full grid runs through ONE jitted, ``vmap``-ed program —
sweeping the radix knob costs one compile, not one per design point.

Two entry points:

* :func:`sweep_barrier` — the Fig. 4 grid: stacked radix tables x
  uniform-scatter delays x trials, all inside a single jit.  The
  per-delay arrivals are the seed's ``uniform_arrivals`` bit-for-bit
  (``uniform(0, d) == d * uniform(0, 1)`` under one key), so results
  match the per-point seed path exactly.
* :func:`simulate_radices` — fixed arrivals (e.g. one kernel's epoch,
  Fig. 6) swept across a radix stack in one call.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from . import barrier
from .barrier import LevelTable
from .barrier_sim import BarrierResult, _scan_core
from .topology import DEFAULT, TeraPoolConfig


class SweepResult(NamedTuple):
    """Per-point timings over a (radix, delay, trial) grid.

    Every field is ``(n_radices, n_delays, n_trials)``; ``radices`` and
    ``delays`` echo the grid axes for self-describing results.
    """

    radices: jnp.ndarray          # (R,) int32
    delays: jnp.ndarray           # (D,) float32
    exit_time: jnp.ndarray        # (R, D, T)
    last_arrival: jnp.ndarray     # (R, D, T)
    span_cycles: jnp.ndarray      # (R, D, T)
    mean_residency: jnp.ndarray   # (R, D, T)

    @property
    def mean_span(self) -> jnp.ndarray:
        """(R, D) Fig. 4a metric, averaged over trials."""
        return jnp.mean(self.span_cycles, axis=-1)

    @property
    def mean_residency_grid(self) -> jnp.ndarray:
        """(R, D) mean per-PE barrier residency, averaged over trials."""
        return jnp.mean(self.mean_residency, axis=-1)


def radix_tables(radices: Sequence[int], n_pes: int | None = None,
                 cfg: TeraPoolConfig = DEFAULT) -> LevelTable:
    """Stacked ``(R, max_levels)`` level tables for a radix sweep."""
    n = int(n_pes if n_pes is not None else cfg.n_pes)
    scheds = [barrier.kary_tree(r, n_pes=n, cfg=cfg) for r in radices]
    return barrier.stack_tables(scheds, cfg)


@partial(jax.jit, static_argnums=(3,))
def _sweep_grid(tables: LevelTable, delays: jnp.ndarray, unit: jnp.ndarray,
                cfg: TeraPoolConfig) -> BarrierResult:
    """(R, D, T) grid through one compiled program.

    ``unit`` is a (T, n_pes) block of standard uniforms; scaling by each
    delay reproduces ``uniform_arrivals`` for that delay exactly.
    """
    arrivals = delays[:, None, None] * unit[None, :, :]      # (D, T, N)
    per_trial = jax.vmap(lambda tab, a: _scan_core(a, tab, cfg),
                         in_axes=(None, 0))                  # over T
    per_delay = jax.vmap(per_trial, in_axes=(None, 0))       # over D
    per_radix = jax.vmap(per_delay, in_axes=(0, None))       # over R
    return per_radix(tables, arrivals)


def sweep_barrier(key: jax.Array, radices: Sequence[int] | None = None,
                  delays: Sequence[float] = (0.0, 128.0, 512.0, 2048.0),
                  n_pes: int | None = None, n_trials: int = 16,
                  cfg: TeraPoolConfig = DEFAULT) -> SweepResult:
    """Run the full radix x delay x trial grid in one compiled call."""
    n = int(n_pes if n_pes is not None else cfg.n_pes)
    if radices is None:
        radices = barrier.all_radices(n, cfg)
    tables = radix_tables(radices, n, cfg)
    unit = jax.random.uniform(key, (n_trials, n), jnp.float32, 0.0, 1.0)
    d = jnp.asarray(delays, jnp.float32)
    res = _sweep_grid(tables, d, unit, cfg)
    return SweepResult(radices=jnp.asarray(list(radices), jnp.int32),
                       delays=d, **res._asdict())


@partial(jax.jit, static_argnums=(2,))
def _radix_stack(tables: LevelTable, arrivals: jnp.ndarray,
                 cfg: TeraPoolConfig) -> BarrierResult:
    return jax.vmap(lambda tab: _scan_core(arrivals, tab, cfg))(tables)


def simulate_radices(arrivals: jnp.ndarray, radices: Sequence[int],
                     cfg: TeraPoolConfig = DEFAULT) -> BarrierResult:
    """Simulate ONE arrival vector under every radix in ``radices``
    (Fig. 6's per-kernel radix scan), vmapped through one compile."""
    arrivals = jnp.asarray(arrivals, jnp.float32)
    tables = radix_tables(radices, arrivals.shape[-1], cfg)
    return _radix_stack(tables, arrivals, cfg)


def best_radix_per_delay(res: SweepResult) -> jnp.ndarray:
    """(D,) radix minimizing the mean Fig. 4a span at each delay."""
    return res.radices[jnp.argmin(res.mean_span, axis=0)]
