"""One-compile design-space sweeps over barrier schedules and arrival
scatters.

The paper's whole result set (Figs. 4-7) is a sweep: barrier schedule x
arrival scatter x Monte-Carlo trial.  Because every schedule over one
cluster shares a padded :class:`~repro.core.barrier.LevelTable` shape,
the full grid runs through ONE jitted, ``vmap``-ed program — sweeping
the schedule knob costs one compile, not one per design point.

Entry points:

* :func:`sweep_schedules` — ANY stack of same-``n_pes`` schedules
  (uniform radices, mixed-radix compositions from
  :mod:`repro.core.tuning`, hand-built trees) x uniform-scatter delays
  x trials, all inside a single jit.  The per-delay arrivals are the
  seed's ``uniform_arrivals`` bit-for-bit (``uniform(0, d) ==
  d * uniform(0, 1)`` under one key), so results match the per-point
  seed path exactly.
* :func:`sweep_barrier` — the Fig. 4 grid: :func:`sweep_schedules`
  specialized to the uniform-radix stack.
* :func:`sweep_arrivals` — DATA-DEPENDENT arrivals: whole stacks of
  measured per-PE arrival matrices (kernel x trial, e.g. the Fig. 5/6
  workload models of :mod:`repro.core.workloads`) swept across a
  schedule (x placement) stack through the same single compile — the
  engine behind the workload-conditioned tuner
  (:func:`repro.core.tuning.sweep_workloads`).
* :func:`simulate_schedules` / :func:`simulate_radices` — fixed
  arrivals (e.g. one kernel's epoch, Fig. 6) swept across a schedule
  stack in one call.

Every entry point takes a ``core`` selector (``"telescope"`` — the
default shrinking-width pyramid — or ``"scan"``, the full-width oracle
core; see :mod:`repro.core.barrier_sim`), a ``trial_chunk`` knob that
splits the Monte-Carlo trial axis into bounded-memory chunks
(bit-for-bit identical to the unchunked grid — trials are
independent), and donates its internally built arrival blocks to the
jitted grids so big sweeps stop being memory-bound on backends with
buffer donation.  When more than one JAX device is visible the grids
are sharded with ``shard_map``: delay grids over the schedule axis
(when it divides evenly), arrival grids over a 2-D schedule x kernel
device mesh whenever that uses more devices than the schedule axis
alone — short hierarchical multi-cluster stacks with many workload
kernels still saturate every device (transparent 2-D -> 1-D ->
single-device fallback: same compiled math, same results).
"""
from __future__ import annotations

import functools
from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import barrier, barrier_sim
from .barrier import LevelTable
from .barrier_sim import BarrierResult, core_fn
from .topology import DEFAULT, TeraPoolConfig


def _stack_radices(schedules: tuple) -> jnp.ndarray:
    """(S,) uniform radix per stacked schedule (0 where mixed-radix)."""
    return jnp.asarray([s.radix for s in schedules], jnp.int32)


def _stack_names(schedules: tuple, placements: tuple) -> tuple:
    """Canonical per-point labels, ``@strategy``-suffixed where an
    explicit placement is attached (shared by both result types)."""
    placs = placements or (None,) * len(schedules)
    return tuple(barrier.schedule_name(s, p)
                 for s, p in zip(schedules, placs))


class SweepResult(NamedTuple):
    """Per-point timings over a (schedule[, placement], delay, trial)
    grid.

    Every array field is ``(n_schedules, n_delays, n_trials)``;
    ``schedules`` (static metadata) and ``delays`` echo the grid axes
    for self-describing results.  ``radices`` is the per-schedule
    uniform radix (0 for mixed-radix compositions).  ``placements``
    aligns with ``schedules`` — one
    :class:`~repro.core.placement.CounterPlacement` (or ``None`` for
    the span-heuristic fallback) per stacked design point; empty on
    placement-free sweeps.
    """

    schedules: tuple              # tuple[BarrierSchedule], length S
    delays: jnp.ndarray           # (D,) float32
    exit_time: jnp.ndarray        # (S, D, T)
    last_arrival: jnp.ndarray     # (S, D, T)
    span_cycles: jnp.ndarray      # (S, D, T)
    mean_residency: jnp.ndarray   # (S, D, T)
    energy: jnp.ndarray           # (S, D, T) episode energy, pJ
    completed: jnp.ndarray        # (S, D, T) bool: barrier released
    abandoned_pes: jnp.ndarray    # (S, D, T) int32 abandoned PEs
    timed_out_levels: jnp.ndarray  # (S, D, T) int32 watchdog releases
    placements: tuple = ()        # tuple[CounterPlacement | None], length S

    @property
    def radices(self) -> jnp.ndarray:
        """(S,) uniform radix per schedule (0 where mixed-radix)."""
        return _stack_radices(self.schedules)

    @property
    def names(self) -> tuple:
        """Canonical schedule names, e.g. ``("2x8x8x8", "8x16x8")``,
        suffixed ``@strategy`` where an explicit placement is attached."""
        return _stack_names(self.schedules, self.placements)

    @property
    def mean_span(self) -> jnp.ndarray:
        """(S, D) Fig. 4a metric, averaged over trials."""
        return jnp.mean(self.span_cycles, axis=-1)

    @property
    def mean_residency_grid(self) -> jnp.ndarray:
        """(S, D) mean per-PE barrier residency, averaged over trials."""
        return jnp.mean(self.mean_residency, axis=-1)

    @property
    def mean_energy(self) -> jnp.ndarray:
        """(S, D) episode energy (pJ), averaged over trials."""
        return jnp.mean(self.energy, axis=-1)

    @property
    def completion_rate(self) -> jnp.ndarray:
        """(S, D) mean fraction of PEs released per barrier episode
        (1.0 everywhere on fault-free sweeps)."""
        n = jnp.float32(self.schedules[0].n_pes)
        return jnp.mean(1.0 - self.abandoned_pes.astype(jnp.float32) / n,
                        axis=-1)


class ArrivalSweepResult(NamedTuple):
    """Per-point timings over a (schedule[, placement], kernel, trial)
    grid — the data-dependent sibling of :class:`SweepResult`.

    Every array field is ``(n_schedules, n_kernels, n_trials)``;
    ``kernels`` echoes the arrival-stack axis (kernel names, or
    positional labels when none were given) and ``schedules`` /
    ``placements`` align exactly as in :class:`SweepResult`.
    """

    schedules: tuple              # tuple[BarrierSchedule], length S
    kernels: tuple                # tuple[str], length K
    exit_time: jnp.ndarray        # (S, K, T)
    last_arrival: jnp.ndarray     # (S, K, T)
    span_cycles: jnp.ndarray      # (S, K, T)
    mean_residency: jnp.ndarray   # (S, K, T)
    energy: jnp.ndarray           # (S, K, T) episode energy, pJ
    completed: jnp.ndarray        # (S, K, T) bool: barrier released
    abandoned_pes: jnp.ndarray    # (S, K, T) int32 abandoned PEs
    timed_out_levels: jnp.ndarray  # (S, K, T) int32 watchdog releases
    placements: tuple = ()        # tuple[CounterPlacement | None], length S

    @property
    def radices(self) -> jnp.ndarray:
        """(S,) uniform radix per schedule (0 where mixed-radix)."""
        return _stack_radices(self.schedules)

    @property
    def names(self) -> tuple:
        """Canonical schedule names, ``@strategy``-suffixed where an
        explicit placement is attached (see :class:`SweepResult`)."""
        return _stack_names(self.schedules, self.placements)

    @property
    def mean_span(self) -> jnp.ndarray:
        """(S, K) Fig. 4a metric per kernel, averaged over trials."""
        return jnp.mean(self.span_cycles, axis=-1)

    @property
    def mean_energy(self) -> jnp.ndarray:
        """(S, K) episode energy (pJ) per kernel, averaged over trials."""
        return jnp.mean(self.energy, axis=-1)

    @property
    def completion_rate(self) -> jnp.ndarray:
        """(S, K) mean fraction of PEs released per barrier episode
        (1.0 everywhere on fault-free sweeps)."""
        n = jnp.float32(self.schedules[0].n_pes)
        return jnp.mean(1.0 - self.abandoned_pes.astype(jnp.float32) / n,
                        axis=-1)


def radix_tables(radices: Sequence[int], n_pes: int | None = None,
                 cfg: TeraPoolConfig = DEFAULT) -> LevelTable:
    """Stacked ``(R, max_levels)`` level tables for a radix sweep."""
    n = int(n_pes if n_pes is not None else cfg.n_pes)
    scheds = [barrier.kary_tree(r, n_pes=n, cfg=cfg) for r in radices]
    return barrier.stack_tables(scheds, cfg)


def _sweep_body(tables: LevelTable, delays: jnp.ndarray, unit: jnp.ndarray,
                cfg: TeraPoolConfig, core: str,
                widths: tuple | None = None) -> BarrierResult:
    """(R, D, T) grid body (unjitted — shared by the plain jit and the
    sharded path).

    ``unit`` is a (T, n_pes) block of standard uniforms; scaling by each
    delay reproduces ``uniform_arrivals`` for that delay exactly.
    ``widths`` is the static telescope width table of the stack
    (``None`` = the conservative in-core default).
    """
    fn = core_fn(core)
    arrivals = delays[:, None, None] * unit[None, :, :]      # (D, T, N)
    per_trial = jax.vmap(lambda tab, a: fn(a, tab, cfg, widths),
                         in_axes=(None, 0))                  # over T
    per_delay = jax.vmap(per_trial, in_axes=(None, 0))       # over D
    per_radix = jax.vmap(per_delay, in_axes=(0, None))       # over R
    return per_radix(tables, arrivals)


# ``unit`` / ``arrivals`` blocks are built (or sliced) fresh by the
# sweep entry points, so the jitted grids donate them: on backends with
# buffer donation the N=1024 512-composition grids reuse the arrival
# block in place instead of holding input + output live (CPU ignores
# donation; results are identical either way).
@partial(jax.jit, static_argnums=(3, 4, 5), donate_argnums=(2,))
def _sweep_grid(tables: LevelTable, delays: jnp.ndarray, unit: jnp.ndarray,
                cfg: TeraPoolConfig, core: str,
                widths: tuple | None) -> BarrierResult:
    """(R, D, T) grid through one compiled program."""
    return _sweep_body(tables, delays, unit, cfg, core, widths)


def _sweep_body_robust(tables: LevelTable, fixed: tuple, unit: jnp.ndarray,
                       cfg: TeraPoolConfig, core: str,
                       widths: tuple | None = None) -> BarrierResult:
    """(R, D, T) grid body under the degradation-tolerant cores.

    ``fixed`` packs ``(delays, fault_spec)`` into the dispatcher's
    single fixed slot; the spec (timeout rows, quorum fraction) is
    traced data broadcast across the whole grid, so sweeping it costs
    zero extra compiles."""
    delays, faults = fixed
    fn = core_fn(core, robust=True)
    arrivals = delays[:, None, None] * unit[None, :, :]      # (D, T, N)
    per_trial = jax.vmap(lambda tab, a: fn(a, tab, cfg, widths, faults),
                         in_axes=(None, 0))                  # over T
    per_delay = jax.vmap(per_trial, in_axes=(None, 0))       # over D
    per_radix = jax.vmap(per_delay, in_axes=(0, None))       # over R
    return per_radix(tables, arrivals)


@partial(jax.jit, static_argnums=(3, 4, 5), donate_argnums=(2,))
def _sweep_grid_robust(tables: LevelTable, fixed: tuple, unit: jnp.ndarray,
                       cfg: TeraPoolConfig, core: str,
                       widths: tuple | None) -> BarrierResult:
    """(R, D, T) timeout/quorum grid through one compiled program."""
    return _sweep_body_robust(tables, fixed, unit, cfg, core, widths)


# ---------------------------------------------------------------------------
# Device sharding: 1-D over the schedule axis, 2-D (schedule x kernel)
# for arrival grids.
# ---------------------------------------------------------------------------

def _grid_devices(n_sched: int, shard: bool, devices=None):
    """The device tuple to shard the schedule axis over, or ``None``
    for the plain single-device path (one device, indivisible stack, or
    sharding disabled).

    ``devices`` overrides the visible-device default — the elastic
    resilient runtime (:mod:`repro.runtime.resilient_sweep`) passes its
    surviving-device tuple here so a sweep continues on a shrunken mesh
    after simulated device loss."""
    if not shard:
        return None
    devs = list(devices) if devices is not None else jax.devices()
    if len(devs) <= 1 or n_sched % len(devs) != 0:
        return None
    return tuple(devs)


def _mesh_shape(n_devices: int, n_sched: int, n_kern: int) -> tuple:
    """The (sched, kern) mesh shape for a 2-D arrival-grid sharding:
    ``ds`` divides the schedule axis, ``dk`` divides the kernel axis,
    ``ds * dk <= n_devices``, maximizing device usage and preferring
    the schedule axis on ties (its shards carry the level tables, the
    bigger per-point state).  ``(1, 1)`` means no useful sharding —
    the transparent single-device fallback.

    This is what lets a 4096-16384-PE multi-cluster grid with a SHORT
    schedule stack (a handful of hierarchical candidates) but many
    workload kernels still saturate all devices: the kernel axis picks
    up the slack the schedule axis leaves."""
    best = (1, 1, 1)                       # (used, ds, dk)
    for ds in range(1, min(n_devices, n_sched) + 1):
        if n_sched % ds:
            continue
        for dk in range(1, n_devices // ds + 1):
            if n_kern % dk:
                continue
            cand = (ds * dk, ds, dk)
            if cand > best:
                best = cand
    return best[1], best[2]


@functools.lru_cache(maxsize=None)
def _sharded_grid(devices: tuple, body: str, cfg: TeraPoolConfig,
                  core: str, widths: tuple | None):
    """Jitted ``shard_map`` of a grid body over a 1-D schedule-axis
    mesh, cached per (devices, body, cfg, core, widths) so repeated
    sweeps reuse one compiled program per shape (the one-compile
    property now holds per device topology x width table)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(np.asarray(devices), ("sched",))
    fn = {"sweep": _sweep_body, "arrival": _arrival_body}[body]
    mapped = shard_map(partial(fn, cfg=cfg, core=core, widths=widths),
                       mesh=mesh,
                       in_specs=(P("sched"), P(), P()),
                       out_specs=P("sched"))
    return jax.jit(mapped, donate_argnums=(2,))


@functools.lru_cache(maxsize=None)
def _sharded_grid_2d(devices: tuple, shape: tuple, cfg: TeraPoolConfig,
                     core: str, widths: tuple | None):
    """Jitted ``shard_map`` of the ARRIVAL grid body over a 2-D
    (schedule x kernel) device mesh: the schedule axis shards the level
    tables, the kernel axis shards the arrival stacks, and each of the
    ``ds * dk`` devices simulates its (S/ds, K/dk) block of the grid.
    Outputs are (S, K, T) arrays sharded over both leading axes."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    ds, dk = shape
    mesh = Mesh(np.asarray(devices).reshape(ds, dk), ("sched", "kern"))
    mapped = shard_map(
        partial(_arrival_body, cfg=cfg, core=core, widths=widths),
        mesh=mesh,
        in_specs=(P("sched"), P(), P("kern")),
        out_specs=P("sched", "kern"))
    return jax.jit(mapped, donate_argnums=(2,))


def _dispatch_grid(body: str, tables: LevelTable, fixed: jnp.ndarray,
                   block: jnp.ndarray, cfg: TeraPoolConfig, core: str,
                   shard: bool, devices=None) -> BarrierResult:
    """Run one grid chunk: 2-D (schedule x kernel) sharded for arrival
    grids when that uses more devices than the schedule axis alone,
    1-D schedule-sharded when several devices divide the stack, plain
    jit otherwise.  ``devices`` restricts the shardable device pool
    (see :func:`_grid_devices`).

    This is the single chokepoint every sweep path (plain AND
    resilient) funnels through, so the stack's telescope width table
    is computed exactly once per chunk here and shared by all of them.
    """
    n_sched = tables.group_sizes.shape[0]
    widths = barrier.telescope_widths(tables, block.shape[-1])
    if body.endswith("_robust"):
        shard = False    # robust grids run unsharded (traced FaultSpec
        #                  in the fixed slot; no shard_map spec for it)
    with barrier_sim.quiet_donation():
        if body == "arrival" and shard:
            devs = (tuple(devices) if devices is not None
                    else tuple(jax.devices()))
            ds, dk = _mesh_shape(len(devs), n_sched, block.shape[0])
            if dk > 1:
                grid = _sharded_grid_2d(devs[:ds * dk], (ds, dk), cfg,
                                        core, widths)
                return grid(tables, fixed, block)
        devices = _grid_devices(n_sched, shard, devices)
        if devices is None:
            grid = {"sweep": _sweep_grid, "arrival": _arrival_grid,
                    "sweep_robust": _sweep_grid_robust,
                    "arrival_robust": _arrival_grid_robust}[body]
            return grid(tables, fixed, block, cfg, core, widths)
        return _sharded_grid(devices, body, cfg, core, widths)(
            tables, fixed, block)


def _trial_chunks(n_trials: int, trial_chunk: int | None):
    """(lo, hi) slices of the trial axis; one full slice when unset."""
    if trial_chunk is None or trial_chunk >= n_trials:
        yield 0, n_trials
        return
    if trial_chunk < 1:
        raise ValueError(f"trial_chunk must be >= 1, got {trial_chunk}")
    for lo in range(0, n_trials, trial_chunk):
        yield lo, min(lo + trial_chunk, n_trials)


def _concat_results(parts: list) -> BarrierResult:
    if len(parts) == 1:
        return parts[0]
    return BarrierResult(*(jnp.concatenate(xs, axis=-1)
                           for xs in zip(*parts)))


def sweep_schedules(key: jax.Array,
                    schedules: Sequence[barrier.BarrierSchedule],
                    delays: Sequence[float] = (0.0, 128.0, 512.0, 2048.0),
                    n_trials: int = 16,
                    cfg: TeraPoolConfig = DEFAULT,
                    placements: Sequence | None = None, *,
                    core: str | None = None,
                    trial_chunk: int | None = None,
                    shard: bool = True,
                    devices=None,
                    faults=None) -> SweepResult:
    """Run ANY same-``n_pes`` schedule stack x delay x trial grid in one
    compiled call — uniform radices, mixed-radix compositions and
    counter placements alike flow through the same jitted program.

    ``placements`` aligns with ``schedules`` (``None`` entries fall
    back to the span heuristic); placed and unplaced points share one
    table shape, so adding the placement axis costs zero extra
    compiles.  ``core`` selects the simulator implementation
    (telescope/scan); ``trial_chunk`` bounds the live grid memory by
    splitting the trial axis (chunked == unchunked bit-for-bit; the
    trial draws happen once, up front); ``shard`` allows splitting the
    schedule axis across visible devices (``devices`` restricts the
    pool to an explicit tuple, e.g. the survivors of a device loss).

    ``faults`` — a :class:`~repro.core.barrier.FaultSpec` from
    :func:`~repro.core.barrier.fault_spec` — switches the grid to the
    degradation-tolerant cores (timeout/quorum release); the spec is
    traced data, so sweeping specs reuses one compiled robust grid."""
    schedules = tuple(schedules)
    tables = barrier.stack_tables(schedules, cfg, placements)
    n = schedules[0].n_pes
    unit = jax.random.uniform(key, (n_trials, n), jnp.float32, 0.0, 1.0)
    d = jnp.asarray(delays, jnp.float32)
    core = barrier_sim.resolve_core(core)
    body = "sweep" if faults is None else "sweep_robust"
    fixed = d if faults is None else (d, faults)
    res = _concat_results([
        _dispatch_grid(body, tables, fixed, jnp.copy(unit[lo:hi]), cfg,
                       core, shard, devices)
        for lo, hi in _trial_chunks(n_trials, trial_chunk)])
    # Placement-free sweeps keep the documented empty tuple (consumers
    # treat () and all-None alike via ``res.placements or ...``).
    placements = tuple(placements) if placements is not None else ()
    return SweepResult(schedules=schedules, delays=d,
                       placements=placements, **res._asdict())


def sweep_barrier(key: jax.Array, radices: Sequence[int] | None = None,
                  delays: Sequence[float] = (0.0, 128.0, 512.0, 2048.0),
                  n_pes: int | None = None, n_trials: int = 16,
                  cfg: TeraPoolConfig = DEFAULT, *,
                  core: str | None = None,
                  trial_chunk: int | None = None,
                  shard: bool = True) -> SweepResult:
    """The Fig. 4 grid: :func:`sweep_schedules` over the uniform-radix
    stack."""
    n = int(n_pes if n_pes is not None else cfg.n_pes)
    if radices is None:
        radices = barrier.all_radices(n, cfg)
    scheds = [barrier.kary_tree(r, n_pes=n, cfg=cfg) for r in radices]
    return sweep_schedules(key, scheds, delays, n_trials, cfg, core=core,
                           trial_chunk=trial_chunk, shard=shard)


def _arrival_body(tables: LevelTable, _unused: jnp.ndarray,
                  arrivals: jnp.ndarray, cfg: TeraPoolConfig,
                  core: str,
                  widths: tuple | None = None) -> BarrierResult:
    """(S, K, T) grid body of data-dependent arrivals (unjitted —
    shared by the plain jit and the sharded paths; ``_unused`` keeps
    the (tables, fixed, block) grid calling convention so both bodies
    share one dispatcher).  ``widths`` is the static telescope width
    table of the stack (``None`` = the conservative in-core default)."""
    fn = core_fn(core)
    per_trial = jax.vmap(lambda tab, a: fn(a, tab, cfg, widths),
                         in_axes=(None, 0))                  # over T
    per_kernel = jax.vmap(per_trial, in_axes=(None, 0))      # over K
    per_sched = jax.vmap(per_kernel, in_axes=(0, None))      # over S
    return per_sched(tables, arrivals)


@partial(jax.jit, static_argnums=(3, 4, 5), donate_argnums=(2,))
def _arrival_grid(tables: LevelTable, _unused: jnp.ndarray,
                  arrivals: jnp.ndarray, cfg: TeraPoolConfig,
                  core: str, widths: tuple | None) -> BarrierResult:
    """(S, K, T) grid of data-dependent arrivals through one compile,
    donating the arrival block (built fresh by :func:`sweep_arrivals`)."""
    return _arrival_body(tables, _unused, arrivals, cfg, core, widths)


def _arrival_body_robust(tables: LevelTable, faults,
                         arrivals: jnp.ndarray, cfg: TeraPoolConfig,
                         core: str,
                         widths: tuple | None = None) -> BarrierResult:
    """(S, K, T) data-dependent grid body under the
    degradation-tolerant cores; the fixed slot carries the traced
    :class:`~repro.core.barrier.FaultSpec` shared by every point."""
    fn = core_fn(core, robust=True)
    per_trial = jax.vmap(lambda tab, a: fn(a, tab, cfg, widths, faults),
                         in_axes=(None, 0))                  # over T
    per_kernel = jax.vmap(per_trial, in_axes=(None, 0))      # over K
    per_sched = jax.vmap(per_kernel, in_axes=(0, None))      # over S
    return per_sched(tables, arrivals)


@partial(jax.jit, static_argnums=(3, 4, 5), donate_argnums=(2,))
def _arrival_grid_robust(tables: LevelTable, faults,
                         arrivals: jnp.ndarray, cfg: TeraPoolConfig,
                         core: str, widths: tuple | None) -> BarrierResult:
    """(S, K, T) timeout/quorum arrival grid through one compile."""
    return _arrival_body_robust(tables, faults, arrivals, cfg, core,
                                widths)


def sweep_arrivals(arrivals: jnp.ndarray,
                   schedules: Sequence[barrier.BarrierSchedule],
                   cfg: TeraPoolConfig = DEFAULT,
                   placements: Sequence | None = None,
                   kernels: Sequence[str] | None = None, *,
                   core: str | None = None,
                   trial_chunk: int | None = None,
                   shard: bool = True,
                   devices=None,
                   faults=None) -> ArrivalSweepResult:
    """Sweep a stack of MEASURED arrival matrices across a schedule
    (x optional placement) stack in one compiled call.

    ``arrivals`` is ``(n_kernels, n_trials, n_pes)`` — e.g. one
    :func:`repro.core.workloads.arrival_batch` per kernel, stacked — or
    ``(n_trials, n_pes)`` for a single workload.  Unlike
    :func:`sweep_schedules`, whose grid is synthesized from uniform
    delays inside the jit, the arrivals here are *data*: any kernel's
    measured scatter (atomic-reduction tails, bimodal border imbalance,
    ...) flows through the same single compiled simulator core, so the
    whole kernel x schedule x placement x trial grid costs one compile
    (trace-count test in tests/test_workload_tuning.py).  ``core`` /
    ``trial_chunk`` / ``shard`` / ``faults`` behave as in
    :func:`sweep_schedules`; fail-stop PEs enter as ``+inf`` arrivals
    in the stacks themselves (see
    :func:`repro.core.workloads.apply_faults`).
    """
    arrivals = jnp.asarray(arrivals, jnp.float32)
    if arrivals.ndim == 2:
        arrivals = arrivals[None]
    if arrivals.ndim != 3:
        raise ValueError(
            f"arrivals must be (n_kernels, n_trials, n_pes) or "
            f"(n_trials, n_pes), got shape {arrivals.shape}")
    schedules = tuple(schedules)
    if schedules and arrivals.shape[-1] != schedules[0].n_pes:
        raise ValueError(
            f"arrivals has {arrivals.shape[-1]} PEs, schedules expect "
            f"{schedules[0].n_pes}")
    if kernels is not None and len(kernels) != arrivals.shape[0]:
        raise ValueError(
            f"{arrivals.shape[0]} arrival stacks but {len(kernels)} "
            f"kernel names")
    tables = barrier.stack_tables(schedules, cfg, placements)
    core = barrier_sim.resolve_core(core)
    n_trials = arrivals.shape[1]
    body = "arrival" if faults is None else "arrival_robust"
    # No delay axis for this body: the fixed slot is a zero-length
    # placeholder, or the traced FaultSpec on robust grids.
    fixed = jnp.zeros((0,), jnp.float32) if faults is None else faults
    res = _concat_results([
        _dispatch_grid(body, tables, fixed,
                       jnp.copy(arrivals[:, lo:hi]), cfg, core, shard,
                       devices)
        for lo, hi in _trial_chunks(n_trials, trial_chunk)])
    kernels = (tuple(kernels) if kernels is not None
               else tuple(f"workload{i}" for i in range(arrivals.shape[0])))
    placements = tuple(placements) if placements is not None else ()
    return ArrivalSweepResult(schedules=schedules, kernels=kernels,
                              placements=placements, **res._asdict())


def split_kernels(res: ArrivalSweepResult) -> list:
    """Decompose a batched arrival sweep into per-kernel single-column
    :class:`ArrivalSweepResult` views (no copy beyond the slice).

    This is the provenance hook of the serving daemon
    (:mod:`repro.runtime.serving`): because the kernel axis is a plain
    vmap batch dimension, slicing column ``j`` out of a batched grid is
    bit-for-bit the result an unbatched single-kernel
    :func:`sweep_arrivals` call would return for the same trace — the
    batching acceptance bar of tests/test_serving.py."""
    return [ArrivalSweepResult(
        schedules=res.schedules, kernels=(k,), placements=res.placements,
        **{f: getattr(res, f)[:, j:j + 1]
           for f in BarrierResult._fields})
        for j, k in enumerate(res.kernels)]


@partial(jax.jit, static_argnums=(2, 3, 4))
def _schedule_stack(tables: LevelTable, arrivals: jnp.ndarray,
                    cfg: TeraPoolConfig, core: str,
                    widths: tuple | None) -> BarrierResult:
    fn = core_fn(core)
    return jax.vmap(lambda tab: fn(arrivals, tab, cfg, widths))(tables)


def simulate_schedules(arrivals: jnp.ndarray,
                       schedules: Sequence[barrier.BarrierSchedule],
                       cfg: TeraPoolConfig = DEFAULT,
                       placements: Sequence | None = None, *,
                       core: str | None = None) -> BarrierResult:
    """Simulate ONE arrival vector under every schedule (x optional
    per-entry placement) in the stack, vmapped through one compile."""
    arrivals = jnp.asarray(arrivals, jnp.float32)
    schedules = tuple(schedules)
    if schedules and arrivals.shape[-1] != schedules[0].n_pes:
        raise ValueError(
            f"arrivals has {arrivals.shape[-1]} PEs, schedules expect "
            f"{schedules[0].n_pes}")
    tables = barrier.stack_tables(schedules, cfg, placements)
    widths = barrier.telescope_widths(tables, arrivals.shape[-1])
    return _schedule_stack(tables, arrivals, cfg,
                           barrier_sim.resolve_core(core), widths)


def simulate_radices(arrivals: jnp.ndarray, radices: Sequence[int],
                     cfg: TeraPoolConfig = DEFAULT, *,
                     core: str | None = None) -> BarrierResult:
    """Simulate ONE arrival vector under every radix in ``radices``
    (Fig. 6's per-kernel radix scan), vmapped through one compile."""
    arrivals = jnp.asarray(arrivals, jnp.float32)
    scheds = [barrier.kary_tree(r, n_pes=arrivals.shape[-1], cfg=cfg)
              for r in radices]
    return simulate_schedules(arrivals, scheds, cfg, core=core)


def best_radix_per_delay(res: SweepResult) -> jnp.ndarray:
    """(D,) radix minimizing the mean Fig. 4a span at each delay.

    Only meaningful for uniform-radix stacks: mixed-radix compositions
    report radix 0.  Prefer :func:`best_schedule_per_delay` for
    arbitrary schedule stacks."""
    return res.radices[jnp.argmin(res.mean_span, axis=0)]


def best_schedule_per_delay(res: SweepResult) -> tuple:
    """(D,) canonical schedule names (``"8x16x8"``,
    ``"2x8x8x8@central"``, ...) minimizing the mean Fig. 4a span at each
    delay — the mixed-radix-safe sibling of :func:`best_radix_per_delay`
    (whose ``radix == 0`` placeholder is meaningless for mixed
    stacks)."""
    names = res.names
    return tuple(names[int(i)]
                 for i in jnp.argmin(res.mean_span, axis=0))
