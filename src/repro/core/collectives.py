"""Hierarchical, radix-tunable collective schedules — the paper's barrier
technique transplanted to TPU pod slices.

The mapping (DESIGN.md §3):

* **flat** — naive DDP: parameters replicated over the data-parallel
  axes, gradients synchronized with ONE all-reduce spanning every chip
  (``pod`` x ``data``).  Every gradient byte crosses the slowest links.
  This is the *central-counter barrier*: all PEs rendezvous on a single
  global object.
* **hierarchical** — ZeRO-3 + two-level tree: parameters sharded over
  ``data``; the backward pass reduce-scatters shard-sized partial sums
  inside each pod (fast intra-pod ICI), and only the 1/16-sized shards
  are all-reduced across the ``pod`` axis.  This is the k-ary tree:
  leaf groups combine locally, only survivors cross the hierarchy.
* **radix-k** — the generalized tree: the data axis is *factored* into
  sub-axes of size k (``make_factored_mesh``) and the reduction runs as
  log_k stages of psum_scatter, mirroring the paper's tunable radix.

Partial synchronization (the paper's Group/Tile wakeup registers) maps
to collectives restricted to a subset of mesh axes: expert-parallel
all-to-alls confined to ``data``, pod-local optimizer reductions, etc.

All functions here run inside a ``jax.shard_map`` whose *manual* axes
include the data-parallel axes; the ``model`` (TP) axis stays *auto* so
GSPMD keeps propagating tensor-parallel shardings through the body.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    """First-class synchronization configuration (the barrier-radix API
    of the paper, Sec. 3: "tuned through a single parameter")."""

    mode: str = "hierarchical"      # "flat" | "hierarchical"
    radix: int = 0                  # 0 = one stage per mesh axis;
                                    # k>0 = factor data axis into radix-k
                                    # sub-axes (needs a factored mesh)
    fsdp: bool = True               # shard params over the data axis
    overlap: bool = True            # per-layer (chunked) gradient sync so
                                    # XLA can overlap with backward compute
    grad_accum_dtype: str = "float32"

    def __post_init__(self):
        if self.mode not in ("flat", "hierarchical"):
            raise ValueError(f"unknown sync mode {self.mode!r}")
        if self.mode == "flat" and self.fsdp:
            # Flat (central-counter) keeps a replicated gradient buffer.
            object.__setattr__(self, "fsdp", False)


FLAT = SyncConfig(mode="flat", fsdp=False)
HIERARCHICAL = SyncConfig(mode="hierarchical", fsdp=True)


# ---------------------------------------------------------------------------
# Mesh construction helpers.
# ---------------------------------------------------------------------------

def data_axes(mesh: jax.sharding.Mesh | jax.sharding.AbstractMesh,
              manual: Sequence[str]) -> tuple:
    """The manual (data-parallel) axes of ``mesh``, slow-to-fast order,
    e.g. ("pod", "data") or ("pod", "data_hi", "data_lo")."""
    return tuple(a for a in mesh.axis_names if a in set(manual))


def shard_map_compat(f, mesh, in_specs, out_specs, manual: Sequence[str]):
    """``jax.shard_map`` with ``manual`` axes, on any supported jax.

    Newer jax spells partial-manual as ``axis_names={...}, check_vma=``;
    jax < 0.5 spells it ``auto=frozenset(other axes), check_rep=`` in
    ``jax.experimental.shard_map``.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  axis_names=set(manual), check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    auto = frozenset(mesh.axis_names) - set(manual)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False, auto=auto)


def make_factored_mesh(radix, *, multi_pod: bool = False,
                       model: int = 16, data: int = 16):
    """A production mesh whose ``data`` axis is factored into sub-axes —
    the radix knob of the tree barrier.  ``radix`` is either an int
    (uniform radix-k factoring, one sub-axis per log_k stage) or a
    sequence of per-stage factors (mixed radix, leaf stage first),
    mirroring :func:`repro.core.barrier.mixed_radix_tree`: e.g.
    ``(4, 2, 2)`` factors ``data=16`` into three reduction stages of
    those sizes.  Device order is identical to
    :func:`repro.launch.mesh.make_production_mesh`, so the physical
    placement is unchanged; only the collective decomposition differs."""
    if isinstance(radix, (tuple, list)):
        sub = tuple(int(f) for f in radix)
        for f in sub:
            if f < 2 or f & (f - 1):
                raise ValueError(
                    f"factors must be powers of two >= 2, got {f}")
        if math.prod(sub) != data:
            raise ValueError(
                f"factors {sub} do not cover data axis {data}")
    else:
        if radix < 2 or radix & (radix - 1):
            raise ValueError("radix must be a power of two >= 2")
        n_sub = max(1, round(math.log(data, radix)))
        if radix ** n_sub != data:
            raise ValueError(
                f"radix {radix} does not factor data axis {data}")
        sub = tuple(radix for _ in range(n_sub))
    names = tuple(f"data{i}" for i in range(len(sub)))
    shape = ((2,) if multi_pod else ()) + sub + (model,)
    axes = (("pod",) if multi_pod else ()) + names + ("model",)
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):
        # jax < 0.5: no AxisType / axis_types kwarg; all axes are Auto.
        return jax.make_mesh(shape, axes)


# ---------------------------------------------------------------------------
# Parameter gather / gradient sync (run inside shard_map manual region).
# ---------------------------------------------------------------------------

_16BIT = (jnp.bfloat16, jnp.float16)


def psum_chain(x: jnp.ndarray, axes: Sequence[str]) -> jnp.ndarray:
    """psum over several mesh axes as a chain of single-axis all-reduces.

    Semantically identical to ``jax.lax.psum(x, tuple(axes))``; chained
    because (a) XLA-CPU's AllReducePromotion pass miscompiles multi-axis
    all-reduces under partial-manual shard_map, and (b) the chain IS the
    paper's tree schedule: one reduction level per hierarchy axis.

    16-bit inputs reduce in f32: numerically safer for gradient sums and
    required on the CPU backend (its AllReducePromotion pass crashes on
    16-bit manual-region reductions).
    """
    if not axes:
        return x
    dt = x.dtype
    if dt in _16BIT:
        x = x.astype(jnp.float32)
    for ax in axes:
        x = jax.lax.psum(x, ax)
    return x.astype(dt)


def scatter_f32(g: jnp.ndarray, ax: str, dim: int) -> jnp.ndarray:
    """reduce-scatter with 16-bit payloads promoted to f32 (see
    psum_chain)."""
    dt = g.dtype
    if dt in _16BIT:
        g = g.astype(jnp.float32)
    g = jax.lax.psum_scatter(g, ax, scatter_dimension=dim, tiled=True)
    return g.astype(dt)


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _gather_one(p: jnp.ndarray, ax: str, dim: int) -> jnp.ndarray:
    return jax.lax.all_gather(p, ax, axis=dim, tiled=True)


def _gather_one_fwd(p, ax, dim):
    return _gather_one(p, ax, dim), None


def _gather_one_bwd(ax, dim, _, g):
    return (scatter_f32(g, ax, dim),)


_gather_one.defvjp(_gather_one_fwd, _gather_one_bwd)


def gather_param(p: jnp.ndarray, axes: Sequence[str], dim: int = 0
                 ) -> jnp.ndarray:
    """ZeRO-3 parameter all-gather over the (possibly factored) data
    axes.  Backward is the reduce-scatter that implements the leaf
    levels of the synchronization tree (f32-promoted, see scatter_f32)."""
    for ax in reversed(axes):          # innermost (fastest) axis last out
        p = _gather_one(p, ax, dim)
    return p


def sync_gradient(g: jnp.ndarray, cfg: SyncConfig, *,
                  pod_axes: Sequence[str],
                  data_axes: Sequence[str]) -> jnp.ndarray:
    """Synchronize one gradient tensor across the data-parallel axes.

    * flat: one all-reduce over every manual axis (central counter).
    * hierarchical: the tensor is assumed already reduce-scattered over
      ``data_axes`` (by the backward of :func:`gather_param`); only the
      shard-sized psum over ``pod_axes`` remains (tree survivors).
    """
    if cfg.mode == "flat":
        return psum_chain(g, tuple(data_axes) + tuple(pod_axes))
    if pod_axes:
        g = psum_chain(g, tuple(pod_axes))
    return g


def tree_psum(x: jnp.ndarray, axes: Sequence[str],
              scatter_dim: int = 0) -> jnp.ndarray:
    """Explicit radix-tree all-reduce: log-stage psum_scatter down the
    axis list, then all-gather back up.  Mathematically equal to
    ``jax.lax.psum(x, axes)`` but lowered as the staged schedule (one
    reduce-scatter/all-gather pair per tree level)."""
    for ax in axes:
        x = jax.lax.psum_scatter(x, ax, scatter_dimension=scatter_dim,
                                 tiled=True)
    for ax in reversed(axes):
        x = jax.lax.all_gather(x, ax, axis=scatter_dim, tiled=True)
    return x


def partial_psum(x: jnp.ndarray, axes: Sequence[str]) -> jnp.ndarray:
    """Partial synchronization: reduce over a *subset* of axes only (the
    Group/Tile wakeup-register analogue)."""
    return psum_chain(x, tuple(axes))


def shard_slice(x: jnp.ndarray, axis_name: str, dim: int = 0) -> jnp.ndarray:
    """Slice the local shard of a replicated tensor (used by the flat
    baseline's optimizer to keep update math identical to FSDP)."""
    idx = jax.lax.axis_index(axis_name)
    size = jax.lax.axis_size(axis_name)
    chunk = x.shape[dim] // size
    return jax.lax.dynamic_slice_in_dim(x, idx * chunk, chunk, dim)
