"""Cycle-level simulator of TeraPool barrier synchronization.

Given per-PE *arrival times* (the cycle at which each PE calls the
barrier), computes the exact timing of the arrival tree under the
machine model of :mod:`repro.core.topology`:

* every PE issues an atomic fetch&add to its group's counter;
* concurrent atomics to one BANK serialize at 1/cycle (single-ported
  bank) — modelled exactly with a max-plus prefix scan over each
  bank's request queue, so sibling counters co-located on one bank
  (see :mod:`repro.core.placement`) contend with each other;
* the group's last arriver observes ``group_size - 1``, resets the
  counter and proceeds to the next level (re-initialization is folded
  into arrival);
* the final survivor writes the memory-mapped wakeup register; the
  wakeup unit raises the hardwired lines and all sleeping PEs resume
  from WFI simultaneously.

Three implementations share the model:

* :func:`_telescope_core` — the production path (``core="telescope"``).
  The schedule is encoded as a fixed-shape, identity-padded
  :class:`~repro.core.barrier.LevelTable` and the level walk is a
  statically unrolled *telescoping pyramid*: step ``i`` touches only
  the first ``widths[i]`` lanes, where ``widths`` is the cumulative-
  quotient survivor bound of the stacked schedules
  (:func:`repro.core.barrier.telescope_widths`; the conservative
  ``max(1, N >> i)`` fallback applies when the stack is traced data).
  Because every real level has group size >= 2 and identity padding is
  tail-only (the canonicalized-table invariant,
  :func:`repro.core.barrier.validate_tail_padding`), the bound is
  sound for power-of-two and non-power-of-two compositions alike — so
  the per-level sort shrinks geometrically (or faster, for hierarchy-
  shaped stacks whose coarse leaf levels collapse the window 8-16x per
  step) and total sort work drops from ``O(N log N · log N)`` (full
  width at every level) to ``O(N log N)`` summed over levels.  Step
  shapes depend only on ``N`` and the per-stack widths tuple, never on
  which schedule in the stack is simulated, so the one-compile
  property over schedule x placement x delay grids is preserved.
* :func:`_scan_core` — the previous production path (``core="scan"``),
  a single jitted ``lax.scan`` at full width per level.  Kept as a
  bit-for-bit oracle for the telescoped core and selectable everywhere
  via ``core="scan"``.
* :func:`simulate_reference` — the original per-level Python loop,
  kept verbatim as the equivalence oracle (tests/test_sweep.py asserts
  all implementations agree bit-for-bit).

Everything is pure JAX and `vmap`-able over Monte-Carlo trials.
"""
from __future__ import annotations

import collections
import contextlib
import os
import warnings
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .barrier import (BarrierSchedule, LevelTable, default_widths,
                      level_table, telescope_widths, validate_tail_padding)
from .energy import (DEFAULT_ENERGY, EnergyModel, episode_energy,
                     schedule_energy_constants)
from .topology import DEFAULT, TeraPoolConfig


@contextlib.contextmanager
def quiet_donation():
    """The jitted simulator entry points donate their arrival blocks
    (memory-bound N=1024 grids reuse the buffer in place where the
    backend supports it); CPU has no buffer donation and would emit an
    advisory once per compile.  Wrap OUR dispatches in this scope so
    the message is silenced for the library's own calls only — never
    process-wide for unrelated user jits."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield

# Incremented once per *trace* of a simulator core ("scan_core" /
# "telescope_core"); jit caching means a whole radix x delay x trial
# sweep costs a single increment.  Tests use it to prove the
# one-compile property.
TRACE_COUNTS = collections.Counter()

# The selectable simulator cores.  "telescope" is the default hot
# path; "scan" is retained as the bit-for-bit oracle (and escape
# hatch, e.g. REPRO_BARRIER_CORE=scan).
CORES = ("telescope", "scan")
DEFAULT_CORE = os.environ.get("REPRO_BARRIER_CORE", "telescope")


def core_traces() -> int:
    """Total traces of ANY simulator core — the quantity the
    one-compile tests bound, independent of which core is active."""
    return sum(TRACE_COUNTS[c + "_core"] for c in CORES)


class BarrierResult(NamedTuple):
    """Timing (cycles) and energy (pJ) of one barrier episode."""

    exit_time: jnp.ndarray        # scalar: cycle at which every PE resumes
    last_arrival: jnp.ndarray     # scalar: cycle the last PE entered
    span_cycles: jnp.ndarray      # exit_time - last_arrival  (Fig. 4a metric)
    mean_residency: jnp.ndarray   # mean over PEs of (exit - own arrival)
    energy: jnp.ndarray           # scalar: episode energy, pJ
                                  # (repro.core.energy.episode_energy)


def _serialize_group(ready: jnp.ndarray, latency: int,
                     cfg: TeraPoolConfig, svc=None) -> jnp.ndarray:
    """Serialize atomics within each group (rows of ``ready``).

    ``ready[g, j]`` is the cycle PE j of group g issues its atomic.  The
    bank services one request per ``bank_service_cycles``; requests are
    served in arrival order.  Returns the completion time of the *last*
    request per group, i.e. when the last arriver has its fetched value.

    With sorted issue times a_(1..k), service start of the j-th request is
        s_j = max_{i<=j} ( a_i + (j - i) * svc )
            = j*svc + cummax( a_j - j*svc )
    — a max-plus prefix scan, fully vectorized.  ``svc`` overrides the
    config's service interval (0 for the hardware event unit, whose
    aggregation stages accept all inputs in parallel).
    """
    svc = cfg.bank_service_cycles if svc is None else svc
    a = jnp.sort(ready, axis=-1)
    j = jnp.arange(a.shape[-1], dtype=a.dtype) * svc
    start = jax.lax.cummax(a - j, axis=a.ndim - 1) + j
    # The response of the final request travels back to the last arriver.
    return start[..., -1] + latency


# ---------------------------------------------------------------------------
# Scanned core over a padded level table (the one-compile path).
# ---------------------------------------------------------------------------

def _segmented_cummax(x: jnp.ndarray, is_start: jnp.ndarray) -> jnp.ndarray:
    """Running max along the last axis that restarts wherever
    ``is_start`` is True (the classic segmented-scan combine, exact for
    max)."""
    def combine(left, right):
        lv, lf = left
        rv, rf = right
        return jnp.where(rf, rv, jnp.maximum(lv, rv)), lf | rf
    v, _ = jax.lax.associative_scan(combine, (x, is_start))
    return v


def _scan_core(arrivals: jnp.ndarray, table: LevelTable,
               cfg: TeraPoolConfig, widths: tuple | None = None
               ) -> BarrierResult:
    """One barrier episode as a ``lax.scan`` over the padded level table.

    ``widths`` is accepted for signature parity with
    :func:`_telescope_core` and ignored: the scan core always runs at
    full width, which is what makes it the width-independent oracle.

    The carried state keeps a fixed shape across levels: ``ready`` is
    always ``(n_pes,)``, with the ``m`` current survivors compacted into
    the prefix ``ready[:m]`` and the tail masked to ``+inf``.

    Atomics serialize per BANK, not per counter: each survivor's
    counter (``index // g``) maps to a bank through the table's
    ``bank_ids`` column, requests are lexsorted by (bank, ready), and
    every bank's queue is one segment of the max-plus service-start
    scan — so sibling counters placed on one bank contend in a single
    shared queue, while conflict-free placements (one bank per
    counter, the default tables) reduce to the seed per-counter
    serialization bit-for-bit.  A counter's last arriver proceeds once
    its own request is serviced, plus that counter's placement-derived
    access latency (``latencies`` column).

    All shapes are fixed and every quantity (group size, banks,
    latencies) is traced data, so any schedule x placement combination
    over one cluster shares this single compiled program.  Identity
    padding levels (g=1, latency=0, instr=0, distinct banks) pass
    timings through unchanged.
    """
    n = arrivals.shape[-1]
    arrivals = jnp.asarray(arrivals, jnp.float32)
    idx = jnp.arange(n)
    width = table.bank_ids.shape[-1]

    # Level 0 entry: call, address computation, atomic issue (or, for
    # the hardware event unit, the single trigger-register store).
    ready0 = arrivals + table.entry_instr

    def step(carry, level):
        ready, m = carry
        g, lat_col, instr, bank_col, svc = level
        grp = idx // g
        # Masked tail slots can index past the counter columns; clip —
        # their +inf ready times sort to the back of any bank queue
        # they land in, so they never perturb live requests.
        bank = bank_col[jnp.minimum(grp, width - 1)]
        order = jnp.lexsort((ready, bank))
        a = ready[order]
        b = bank[order]
        gs = grp[order]
        # Per-bank queues: rank = position within the bank segment;
        # service start of request j is rank*svc + max over earlier
        # same-bank requests of (a - rank*svc) — the same max-plus
        # reduction as _serialize_group, segmented by bank.
        is_start = jnp.concatenate(
            [jnp.ones((1,), bool), b[1:] != b[:-1]])
        seg_first = jax.lax.cummax(jnp.where(is_start, idx, 0))
        rank = (idx - seg_first).astype(jnp.float32)
        start = _segmented_cummax(a - rank * svc, is_start) + rank * svc
        # The counter's last arriver is its latest-serviced request; the
        # fetched value travels back at the counter's access latency.
        last = jax.ops.segment_max(start, gs, num_segments=n)
        done = last + lat_col[jnp.minimum(idx, width - 1)]
        # Survivors run the compare/branch + counter-reset + next-level
        # setup before issuing the next atomic; compact them to the
        # prefix and re-mask the tail.
        m = m // g
        ready = jnp.where(idx < m, done + instr, jnp.inf)
        return (ready, m), None

    TRACE_COUNTS["scan_core"] += 1
    levels = (table.group_sizes, table.latencies, table.instr_cycles,
              table.bank_ids, table.service_cycles)
    (ready, _), _ = jax.lax.scan(step, (ready0, jnp.int32(n)), levels)

    exit_time = ready[0] + cfg.wakeup_cycles
    last_arrival = jnp.max(arrivals, axis=-1)
    mean_res = jnp.mean(exit_time[..., None] - arrivals, axis=-1)
    return BarrierResult(
        exit_time=exit_time,
        last_arrival=last_arrival,
        span_cycles=exit_time - last_arrival,
        mean_residency=mean_res,
        energy=episode_energy(table.energy_static, table.active_cycles,
                              table.idle_power, n, mean_res),
    )


# ---------------------------------------------------------------------------
# Telescoping pyramid core: statically unrolled shrinking-width steps.
# ---------------------------------------------------------------------------

def _telescope_core(arrivals: jnp.ndarray, table: LevelTable,
                    cfg: TeraPoolConfig, widths: tuple | None = None
                    ) -> BarrierResult:
    """One barrier episode as a telescoping pyramid of unrolled steps.

    Step ``i`` operates on only the first ``widths[i]`` lanes — the
    *cumulative-quotient* survivor bound of the stacked schedules
    (:func:`repro.core.barrier.telescope_widths`), or the conservative
    ``max(1, N >> i)`` of :func:`repro.core.barrier.default_widths`
    when ``widths`` is ``None`` (e.g. called with traced tables).  Any
    upper bound on the live count is sound under the canonical-table
    invariant (identity padding is tail-only, :func:`repro.core.
    barrier.validate_tail_padding`): every real level divides the live
    count by its group size ``g >= 2`` — floored division composes, so
    non-power-of-two level sizes keep the bound exact — and once
    padding starts the single final survivor trivially fits any later
    width.  Masked tail lanes inside a step's window carry ``+inf``
    exactly as in :func:`_scan_core`; lanes beyond the window hold
    only ``+inf`` phantoms, which sort to the back of their bank
    queues and never feed a live counter — so shrinking the window
    changes no live lane's float trajectory and the two cores agree
    bit for bit at every width table (tests/test_telescope.py,
    tests/test_multicluster.py).

    Inside each step the two-pass ``jnp.lexsort((ready, bank))`` of the
    scanned core becomes a single stable multi-key ``lax.sort`` over
    ``(bank, ready)`` that co-sorts the group ids, and the per-bank
    rank is derived with a ``searchsorted`` of the sorted bank column
    into itself (first occurrence = segment start) instead of a second
    ``cummax`` pass.  Only the max-plus service-start scan remains a
    scan.

    Step widths are a STATIC tuple shared by the whole stacked sweep
    (one widths table per grid, computed host-side from the concrete
    stack); group sizes, banks and latencies stay traced data — so any
    schedule x placement combination over one stacked grid shares this
    single compiled program, exactly like the scanned core.
    """
    n = arrivals.shape[-1]
    arrivals = jnp.asarray(arrivals, jnp.float32)
    width = table.bank_ids.shape[-1]
    depth = table.group_sizes.shape[-1]

    if widths is None:
        widths = default_widths(n, depth)
    if len(widths) != depth + 1:
        raise ValueError(
            f"widths table has {len(widths)} entries for a depth-"
            f"{depth} table; need depth + 1")

    TRACE_COUNTS["telescope_core"] += 1

    # Level 0 entry: call, address computation, atomic issue (or, for
    # the hardware event unit, the single trigger-register store).
    ready = arrivals + table.entry_instr
    m = jnp.int32(n)
    for i in range(depth):
        w = min(int(widths[i]), n)
        ready = ready[:w]
        idx = jnp.arange(w)
        g = table.group_sizes[i]
        svc = table.service_cycles[i]
        grp = idx // g
        # Masked tail slots can index past the counter columns; clip —
        # their +inf ready times sort to the back of any bank queue
        # they land in, so they never perturb live requests.
        bank = table.bank_ids[i][jnp.minimum(grp, width - 1)]
        b, a, gs = jax.lax.sort((bank, ready, grp), num_keys=2)
        # Per-bank queues: the sorted bank column's first occurrence of
        # each bank is its segment start, so rank = idx - first.
        first = jnp.searchsorted(b, b, side="left")
        rank = (idx - first).astype(jnp.float32)
        is_start = jnp.concatenate(
            [jnp.ones((1,), bool), b[1:] != b[:-1]])
        start = _segmented_cummax(a - rank * svc, is_start) + rank * svc
        # The counter's last arriver is its latest-serviced request; the
        # fetched value travels back at the counter's access latency.
        last = jax.ops.segment_max(start, gs, num_segments=w)
        done = last + table.latencies[i][jnp.minimum(idx, width - 1)]
        # Survivors run the compare/branch + counter-reset + next-level
        # setup, then compact into the next (shrunken) window.
        m = m // g
        w_next = min(int(widths[i + 1]), w)
        ready = jnp.where(jnp.arange(w_next) < m,
                          done[:w_next] + table.instr_cycles[i], jnp.inf)

    exit_time = ready[0] + cfg.wakeup_cycles
    last_arrival = jnp.max(arrivals, axis=-1)
    mean_res = jnp.mean(exit_time[..., None] - arrivals, axis=-1)
    return BarrierResult(
        exit_time=exit_time,
        last_arrival=last_arrival,
        span_cycles=exit_time - last_arrival,
        mean_residency=mean_res,
        energy=episode_energy(table.energy_static, table.active_cycles,
                              table.idle_power, n, mean_res),
    )


_CORE_FNS = {"scan": _scan_core, "telescope": _telescope_core}


def resolve_core(core: str | None = None) -> str:
    """Normalize a core selector (``"telescope"`` | ``"scan"`` |
    ``None`` for the session default) to a validated core name — the
    static-argument form every jitted entry point shares."""
    name = DEFAULT_CORE if core is None else core
    if name not in _CORE_FNS:
        raise ValueError(
            f"unknown simulator core {name!r}; choose from {CORES}")
    return name


def core_fn(core: str | None = None):
    """Resolve a core selector to its implementation."""
    return _CORE_FNS[resolve_core(core)]


@partial(jax.jit, static_argnums=(2, 3, 4), donate_argnums=(0,))
def _simulate_flat(arrivals: jnp.ndarray, table: LevelTable,
                   cfg: TeraPoolConfig, core: str,
                   widths: tuple | None) -> BarrierResult:
    """Jitted (trials, n_pes) batch of the selected core.  The arrival
    block is donated: it is a flattened copy owned by
    :func:`simulate_table`, so its buffer can be reused in place on
    backends that support donation.  ``widths`` is the static
    telescope width table (``None`` = the conservative default)."""
    fn = core_fn(core)
    return jax.vmap(lambda a: fn(a, table, cfg, widths))(arrivals)


def simulate_table(arrivals: jnp.ndarray, table: LevelTable,
                   cfg: TeraPoolConfig = DEFAULT, *,
                   core: str | None = None) -> BarrierResult:
    """Simulate directly from a padded :class:`LevelTable`.

    Accepts any leading batch shape on ``arrivals``; all batch entries
    run through one jitted, vmapped program.  ``core`` selects the
    simulator implementation (default :data:`DEFAULT_CORE`).
    """
    # Light check (group-size column only): tables from level_table /
    # stack_tables were fully validated at construction; this guards
    # hand-built tables without a per-call host sync of the big
    # latency columns.
    table = validate_tail_padding(table, full=False)
    arrivals = jnp.asarray(arrivals, jnp.float32)
    batch = arrivals.shape[:-1]
    widths = telescope_widths(table, arrivals.shape[-1])
    # jnp.copy guarantees _simulate_flat donates a private buffer, never
    # the caller's array (asarray/reshape can alias their input).
    flat = jnp.copy(arrivals.reshape((-1, arrivals.shape[-1])))
    with quiet_donation():
        res = _simulate_flat(flat, table, cfg, resolve_core(core), widths)
    return BarrierResult(*(x.reshape(batch) for x in res))


def simulate(arrivals: jnp.ndarray, schedule: BarrierSchedule,
             cfg: TeraPoolConfig = DEFAULT, *,
             placement=None, core: str | None = None,
             energy_model: EnergyModel = DEFAULT_ENERGY) -> BarrierResult:
    """Simulate one barrier episode (or a leading batch of them).

    Args:
      arrivals: (..., n_pes) per-PE barrier-entry cycles (float or int).
      schedule: static tree structure from :mod:`repro.core.barrier`.
      cfg: machine model.
      placement: optional :class:`~repro.core.placement.CounterPlacement`
        mapping every counter to a concrete bank; ``None`` uses the
        legacy span-heuristic latencies with conflict-free banks.
      core: simulator implementation, ``"telescope"`` (default) or
        ``"scan"`` (the bit-for-bit oracle core).
      energy_model: per-event cost model pricing the ``energy`` column
        (:mod:`repro.core.energy`).

    Returns:
      :class:`BarrierResult` with the leading batch shape of ``arrivals``.
    """
    arrivals = jnp.asarray(arrivals, jnp.float32)
    if arrivals.shape[-1] != schedule.n_pes:
        raise ValueError(
            f"arrivals has {arrivals.shape[-1]} PEs, schedule expects "
            f"{schedule.n_pes}")
    table = level_table(schedule, cfg=cfg, placement=placement,
                        energy_model=energy_model)
    return simulate_table(arrivals, table, cfg, core=core)


def simulate_reference(arrivals: jnp.ndarray, schedule: BarrierSchedule,
                       cfg: TeraPoolConfig = DEFAULT,
                       energy_model: EnergyModel = DEFAULT_ENERGY
                       ) -> BarrierResult:
    """The seed per-level Python loop, kept as the equivalence oracle.

    Retraces per schedule (shape-changing reshapes); use only in tests
    and spot checks.
    """
    arrivals = jnp.asarray(arrivals, jnp.float32)
    if arrivals.shape[-1] != schedule.n_pes:
        raise ValueError(
            f"arrivals has {arrivals.shape[-1]} PEs, schedule expects "
            f"{schedule.n_pes}")

    # The hardware event unit replaces the software level path: one
    # trigger store on entry, parallel (unserialized) stage
    # aggregation, zero per-level bookkeeping.
    hw = schedule.hw
    entry = cfg.hw_entry_instr if hw else cfg.instr_per_level
    instr = 0 if hw else cfg.instr_per_level
    svc = 0 if hw else None

    # Ready time of the survivors entering the current level.  Level 0:
    # every PE, offset by the per-level software path (call, address
    # computation, atomic issue).
    ready = arrivals + entry
    for lvl in schedule.levels:
        grouped = ready.reshape(ready.shape[:-1] + (-1, lvl.group_size))
        done = _serialize_group(grouped, lvl.latency, cfg, svc=svc)
        # Survivors run the compare/branch + counter-reset + next-level
        # setup before issuing the next atomic.
        ready = done + instr

    # ``ready`` is now (..., 1): the final survivor after its bookkeeping.
    final = ready[..., 0]
    exit_time = final + cfg.wakeup_cycles
    last_arrival = jnp.max(arrivals, axis=-1)
    mean_res = jnp.mean(exit_time[..., None] - arrivals, axis=-1)
    stat, act, idle = schedule_energy_constants(
        schedule, None, cfg, energy_model)
    return BarrierResult(
        exit_time=exit_time,
        last_arrival=last_arrival,
        span_cycles=exit_time - last_arrival,
        mean_residency=mean_res,
        energy=episode_energy(jnp.float32(stat), jnp.float32(act),
                              jnp.float32(idle), schedule.n_pes, mean_res),
    )


def uniform_arrivals(key: jax.Array, max_delay: float, n_pes: int,
                     n_trials: int = 16) -> jnp.ndarray:
    """The paper's synthetic benchmark (Sec. 4.1): per-PE delay drawn
    uniformly from [0, max_delay]."""
    if max_delay <= 0:
        return jnp.zeros((n_trials, n_pes), jnp.float32)
    return jax.random.uniform(key, (n_trials, n_pes), jnp.float32,
                              0.0, max_delay)


def mean_span_cycles(key: jax.Array, schedule: BarrierSchedule,
                     max_delay: float, cfg: TeraPoolConfig = DEFAULT,
                     n_trials: int = 16) -> jnp.ndarray:
    """Average Fig. 4a metric (last-in -> last-out cycles) over trials."""
    arr = uniform_arrivals(key, max_delay, schedule.n_pes, n_trials)
    return jnp.mean(simulate(arr, schedule, cfg).span_cycles)


def overhead_fraction(key: jax.Array, schedule: BarrierSchedule,
                      sfr_cycles: float, max_delay: float,
                      cfg: TeraPoolConfig = DEFAULT,
                      n_trials: int = 16) -> jnp.ndarray:
    """Fig. 4b metric: mean per-PE barrier residency over total runtime,
    as a function of the synchronization-free region (SFR)."""
    arr = uniform_arrivals(key, max_delay, schedule.n_pes, n_trials)
    res = simulate(arr, schedule, cfg)
    barrier = jnp.mean(res.mean_residency)
    return barrier / (sfr_cycles + barrier)
