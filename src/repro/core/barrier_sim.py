"""Cycle-level simulator of TeraPool barrier synchronization.

Given per-PE *arrival times* (the cycle at which each PE calls the
barrier), computes the exact timing of the arrival tree under the
machine model of :mod:`repro.core.topology`:

* every PE issues an atomic fetch&add to its group's counter;
* concurrent atomics to one BANK serialize at 1/cycle (single-ported
  bank) — modelled exactly with a max-plus prefix scan over each
  bank's request queue, so sibling counters co-located on one bank
  (see :mod:`repro.core.placement`) contend with each other;
* the group's last arriver observes ``group_size - 1``, resets the
  counter and proceeds to the next level (re-initialization is folded
  into arrival);
* the final survivor writes the memory-mapped wakeup register; the
  wakeup unit raises the hardwired lines and all sleeping PEs resume
  from WFI simultaneously.

Three implementations share the model:

* :func:`_telescope_core` — the production path (``core="telescope"``).
  The schedule is encoded as a fixed-shape, identity-padded
  :class:`~repro.core.barrier.LevelTable` and the level walk is a
  statically unrolled *telescoping pyramid*: step ``i`` touches only
  the first ``widths[i]`` lanes, where ``widths`` is the cumulative-
  quotient survivor bound of the stacked schedules
  (:func:`repro.core.barrier.telescope_widths`; the conservative
  ``max(1, N >> i)`` fallback applies when the stack is traced data).
  Because every real level has group size >= 2 and identity padding is
  tail-only (the canonicalized-table invariant,
  :func:`repro.core.barrier.validate_tail_padding`), the bound is
  sound for power-of-two and non-power-of-two compositions alike — so
  the per-level sort shrinks geometrically (or faster, for hierarchy-
  shaped stacks whose coarse leaf levels collapse the window 8-16x per
  step) and total sort work drops from ``O(N log N · log N)`` (full
  width at every level) to ``O(N log N)`` summed over levels.  Step
  shapes depend only on ``N`` and the per-stack widths tuple, never on
  which schedule in the stack is simulated, so the one-compile
  property over schedule x placement x delay grids is preserved.
* :func:`_scan_core` — the previous production path (``core="scan"``),
  a single jitted ``lax.scan`` at full width per level.  Kept as a
  bit-for-bit oracle for the telescoped core and selectable everywhere
  via ``core="scan"``.
* :func:`simulate_reference` — the original per-level Python loop,
  kept verbatim as the equivalence oracle (tests/test_sweep.py asserts
  all implementations agree bit-for-bit).

Everything is pure JAX and `vmap`-able over Monte-Carlo trials.

Fault model
-----------

Both cores have degradation-tolerant twins (``faults=`` on
:func:`simulate` / :func:`simulate_table`, dispatched to
:func:`_scan_robust_core` / :func:`_telescope_robust_core`) that model
what a real 1024-PE machine does when a PE never shows up:

* **Fail-stop** is an arrival of ``+inf`` — the same masked-lane
  convention the padded tables already use — so a per-PE fault mask is
  ordinary traced data (``fault_mask=``, applied as
  ``where(mask, +inf, arrivals)``) and composes with the
  fault-conditioned samplers of :mod:`repro.core.workloads`
  (stragglers, transient stalls) without recompiling anything.
* **Timeout release**: each counter arms a watchdog when it services
  its FIRST child and force-releases ``timeout_cycles`` later even if
  children are missing (the hardware-synchronizer bound of Glaser et
  al., arXiv 2004.06662).
* **Quorum release**: a counter over ``g`` children releases once
  ``ceil(quorum_frac * g)`` have been serviced (K-of-N semantics; for
  the central counter this is exactly K of N PEs, for trees the
  per-counter generalization).

Children still missing at a release are *abandoned*: their whole
original-PE subtree is charged to ``abandoned_pes``, and their late
arrival can no longer block any ancestor (an un-released subtree
carries ``+inf`` upward and is abandoned higher up, or — with no
timeout anywhere — deadlocks the episode: ``exit_time = +inf``,
``completed = False``).  :class:`BarrierResult` reports per episode
``completed`` / ``abandoned_pes`` / ``timed_out_levels``; span and
residency are computed over the surviving PEs.  With no faults
injected, ``timeout = +inf`` and ``quorum_frac = 1.0``, every robust
column is bit-for-bit the plain core's output (the release algebra
degenerates through IEEE identities: ``min(x, +inf) = x``,
``x * 1.0 = x``), and :func:`simulate_robust_reference` — an
independent numpy per-bank-queue walk with explicit quorum/timeout
bookkeeping — is the oracle the robust cores are validated against
bit-for-bit (tests/test_faults.py).
"""
from __future__ import annotations

import collections
import contextlib
import os
import warnings
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .barrier import (BarrierSchedule, FaultSpec, LevelTable,
                      default_widths, fault_spec, level_table,
                      telescope_widths, validate_tail_padding)
from .energy import (DEFAULT_ENERGY, EnergyModel, episode_energy,
                     robust_episode_energy, schedule_energy_constants)
from .topology import DEFAULT, TeraPoolConfig


@contextlib.contextmanager
def quiet_donation():
    """The jitted simulator entry points donate their arrival blocks
    (memory-bound N=1024 grids reuse the buffer in place where the
    backend supports it); CPU has no buffer donation and would emit an
    advisory once per compile.  Wrap OUR dispatches in this scope so
    the message is silenced for the library's own calls only — never
    process-wide for unrelated user jits."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield

# Incremented once per *trace* of a simulator core ("scan_core" /
# "telescope_core"); jit caching means a whole radix x delay x trial
# sweep costs a single increment.  Tests use it to prove the
# one-compile property.
TRACE_COUNTS = collections.Counter()

# The selectable simulator cores.  "telescope" is the default hot
# path; "scan" is retained as the bit-for-bit oracle (and escape
# hatch, e.g. REPRO_BARRIER_CORE=scan).
CORES = ("telescope", "scan")
DEFAULT_CORE = os.environ.get("REPRO_BARRIER_CORE", "telescope")


def core_traces() -> int:
    """Total traces of ANY simulator core — the quantity the
    one-compile tests bound, independent of which core is active.
    Robust (fault-model) core variants count like their plain twins."""
    return sum(TRACE_COUNTS[c + "_core"] + TRACE_COUNTS[c + "_robust_core"]
               for c in CORES)


class BarrierResult(NamedTuple):
    """Timing (cycles), energy (pJ) and degradation accounting of one
    barrier episode.

    The last three columns are the fault-model telemetry.  The plain
    (fault-free) cores fill them trivially — ``completed`` is finite
    exit, zero abandonment, zero watchdog releases — so every result
    type downstream (sweeps, tuner grids, checkpoints) carries one
    uniform set of columns whether or not faults were simulated.
    """

    exit_time: jnp.ndarray        # scalar: cycle at which every PE resumes
    last_arrival: jnp.ndarray     # scalar: cycle the last PE entered
    span_cycles: jnp.ndarray      # exit_time - last_arrival  (Fig. 4a metric)
    mean_residency: jnp.ndarray   # mean over PEs of (exit - own arrival);
                                  # under faults: over the SURVIVING PEs
    energy: jnp.ndarray           # scalar: episode energy, pJ
                                  # (repro.core.energy.episode_energy)
    completed: jnp.ndarray        # bool: the barrier released (finite exit)
    abandoned_pes: jnp.ndarray    # int32: PEs the tree gave up on
                                  # (fail-stop + timeout/quorum drops)
    timed_out_levels: jnp.ndarray  # int32: levels with >= 1 watchdog release


def _serialize_group(ready: jnp.ndarray, latency: int,
                     cfg: TeraPoolConfig, svc=None) -> jnp.ndarray:
    """Serialize atomics within each group (rows of ``ready``).

    ``ready[g, j]`` is the cycle PE j of group g issues its atomic.  The
    bank services one request per ``bank_service_cycles``; requests are
    served in arrival order.  Returns the completion time of the *last*
    request per group, i.e. when the last arriver has its fetched value.

    With sorted issue times a_(1..k), service start of the j-th request is
        s_j = max_{i<=j} ( a_i + (j - i) * svc )
            = j*svc + cummax( a_j - j*svc )
    — a max-plus prefix scan, fully vectorized.  ``svc`` overrides the
    config's service interval (0 for the hardware event unit, whose
    aggregation stages accept all inputs in parallel).
    """
    svc = cfg.bank_service_cycles if svc is None else svc
    a = jnp.sort(ready, axis=-1)
    j = jnp.arange(a.shape[-1], dtype=a.dtype) * svc
    start = jax.lax.cummax(a - j, axis=a.ndim - 1) + j
    # The response of the final request travels back to the last arriver.
    return start[..., -1] + latency


# ---------------------------------------------------------------------------
# Scanned core over a padded level table (the one-compile path).
# ---------------------------------------------------------------------------

def _segmented_cummax(x: jnp.ndarray, is_start: jnp.ndarray) -> jnp.ndarray:
    """Running max along the last axis that restarts wherever
    ``is_start`` is True (the classic segmented-scan combine, exact for
    max)."""
    def combine(left, right):
        lv, lf = left
        rv, rf = right
        return jnp.where(rf, rv, jnp.maximum(lv, rv)), lf | rf
    v, _ = jax.lax.associative_scan(combine, (x, is_start))
    return v


def _scan_core(arrivals: jnp.ndarray, table: LevelTable,
               cfg: TeraPoolConfig, widths: tuple | None = None
               ) -> BarrierResult:
    """One barrier episode as a ``lax.scan`` over the padded level table.

    ``widths`` is accepted for signature parity with
    :func:`_telescope_core` and ignored: the scan core always runs at
    full width, which is what makes it the width-independent oracle.

    The carried state keeps a fixed shape across levels: ``ready`` is
    always ``(n_pes,)``, with the ``m`` current survivors compacted into
    the prefix ``ready[:m]`` and the tail masked to ``+inf``.

    Atomics serialize per BANK, not per counter: each survivor's
    counter (``index // g``) maps to a bank through the table's
    ``bank_ids`` column, requests are lexsorted by (bank, ready), and
    every bank's queue is one segment of the max-plus service-start
    scan — so sibling counters placed on one bank contend in a single
    shared queue, while conflict-free placements (one bank per
    counter, the default tables) reduce to the seed per-counter
    serialization bit-for-bit.  A counter's last arriver proceeds once
    its own request is serviced, plus that counter's placement-derived
    access latency (``latencies`` column).

    All shapes are fixed and every quantity (group size, banks,
    latencies) is traced data, so any schedule x placement combination
    over one cluster shares this single compiled program.  Identity
    padding levels (g=1, latency=0, instr=0, distinct banks) pass
    timings through unchanged.
    """
    n = arrivals.shape[-1]
    arrivals = jnp.asarray(arrivals, jnp.float32)
    idx = jnp.arange(n)
    width = table.bank_ids.shape[-1]

    # Level 0 entry: call, address computation, atomic issue (or, for
    # the hardware event unit, the single trigger-register store).
    ready0 = arrivals + table.entry_instr

    def step(carry, level):
        ready, m = carry
        g, lat_col, instr, bank_col, svc = level
        grp = idx // g
        # Masked tail slots can index past the counter columns; clip —
        # their +inf ready times sort to the back of any bank queue
        # they land in, so they never perturb live requests.
        bank = bank_col[jnp.minimum(grp, width - 1)]
        order = jnp.lexsort((ready, bank))
        a = ready[order]
        b = bank[order]
        gs = grp[order]
        # Per-bank queues: rank = position within the bank segment;
        # service start of request j is rank*svc + max over earlier
        # same-bank requests of (a - rank*svc) — the same max-plus
        # reduction as _serialize_group, segmented by bank.
        is_start = jnp.concatenate(
            [jnp.ones((1,), bool), b[1:] != b[:-1]])
        seg_first = jax.lax.cummax(jnp.where(is_start, idx, 0))
        rank = (idx - seg_first).astype(jnp.float32)
        start = _segmented_cummax(a - rank * svc, is_start) + rank * svc
        # The counter's last arriver is its latest-serviced request; the
        # fetched value travels back at the counter's access latency.
        last = jax.ops.segment_max(start, gs, num_segments=n)
        done = last + lat_col[jnp.minimum(idx, width - 1)]
        # Survivors run the compare/branch + counter-reset + next-level
        # setup before issuing the next atomic; compact them to the
        # prefix and re-mask the tail.
        m = m // g
        ready = jnp.where(idx < m, done + instr, jnp.inf)
        return (ready, m), None

    TRACE_COUNTS["scan_core"] += 1
    levels = (table.group_sizes, table.latencies, table.instr_cycles,
              table.bank_ids, table.service_cycles)
    (ready, _), _ = jax.lax.scan(step, (ready0, jnp.int32(n)), levels)

    exit_time = ready[0] + cfg.wakeup_cycles
    last_arrival = jnp.max(arrivals, axis=-1)
    mean_res = jnp.mean(exit_time[..., None] - arrivals, axis=-1)
    return BarrierResult(
        exit_time=exit_time,
        last_arrival=last_arrival,
        span_cycles=exit_time - last_arrival,
        mean_residency=mean_res,
        energy=episode_energy(table.energy_static, table.active_cycles,
                              table.idle_power, n, mean_res),
        completed=jnp.isfinite(exit_time),
        abandoned_pes=jnp.int32(0),
        timed_out_levels=jnp.int32(0),
    )


# ---------------------------------------------------------------------------
# Telescoping pyramid core: statically unrolled shrinking-width steps.
# ---------------------------------------------------------------------------

def _telescope_core(arrivals: jnp.ndarray, table: LevelTable,
                    cfg: TeraPoolConfig, widths: tuple | None = None
                    ) -> BarrierResult:
    """One barrier episode as a telescoping pyramid of unrolled steps.

    Step ``i`` operates on only the first ``widths[i]`` lanes — the
    *cumulative-quotient* survivor bound of the stacked schedules
    (:func:`repro.core.barrier.telescope_widths`), or the conservative
    ``max(1, N >> i)`` of :func:`repro.core.barrier.default_widths`
    when ``widths`` is ``None`` (e.g. called with traced tables).  Any
    upper bound on the live count is sound under the canonical-table
    invariant (identity padding is tail-only, :func:`repro.core.
    barrier.validate_tail_padding`): every real level divides the live
    count by its group size ``g >= 2`` — floored division composes, so
    non-power-of-two level sizes keep the bound exact — and once
    padding starts the single final survivor trivially fits any later
    width.  Masked tail lanes inside a step's window carry ``+inf``
    exactly as in :func:`_scan_core`; lanes beyond the window hold
    only ``+inf`` phantoms, which sort to the back of their bank
    queues and never feed a live counter — so shrinking the window
    changes no live lane's float trajectory and the two cores agree
    bit for bit at every width table (tests/test_telescope.py,
    tests/test_multicluster.py).

    Inside each step the two-pass ``jnp.lexsort((ready, bank))`` of the
    scanned core becomes a single stable multi-key ``lax.sort`` over
    ``(bank, ready)`` that co-sorts the group ids, and the per-bank
    rank is derived with a ``searchsorted`` of the sorted bank column
    into itself (first occurrence = segment start) instead of a second
    ``cummax`` pass.  Only the max-plus service-start scan remains a
    scan.

    Step widths are a STATIC tuple shared by the whole stacked sweep
    (one widths table per grid, computed host-side from the concrete
    stack); group sizes, banks and latencies stay traced data — so any
    schedule x placement combination over one stacked grid shares this
    single compiled program, exactly like the scanned core.
    """
    n = arrivals.shape[-1]
    arrivals = jnp.asarray(arrivals, jnp.float32)
    width = table.bank_ids.shape[-1]
    depth = table.group_sizes.shape[-1]

    if widths is None:
        widths = default_widths(n, depth)
    if len(widths) != depth + 1:
        raise ValueError(
            f"widths table has {len(widths)} entries for a depth-"
            f"{depth} table; need depth + 1")

    TRACE_COUNTS["telescope_core"] += 1

    # Level 0 entry: call, address computation, atomic issue (or, for
    # the hardware event unit, the single trigger-register store).
    ready = arrivals + table.entry_instr
    m = jnp.int32(n)
    for i in range(depth):
        w = min(int(widths[i]), n)
        ready = ready[:w]
        idx = jnp.arange(w)
        g = table.group_sizes[i]
        svc = table.service_cycles[i]
        grp = idx // g
        # Masked tail slots can index past the counter columns; clip —
        # their +inf ready times sort to the back of any bank queue
        # they land in, so they never perturb live requests.
        bank = table.bank_ids[i][jnp.minimum(grp, width - 1)]
        b, a, gs = jax.lax.sort((bank, ready, grp), num_keys=2)
        # Per-bank queues: the sorted bank column's first occurrence of
        # each bank is its segment start, so rank = idx - first.
        first = jnp.searchsorted(b, b, side="left")
        rank = (idx - first).astype(jnp.float32)
        is_start = jnp.concatenate(
            [jnp.ones((1,), bool), b[1:] != b[:-1]])
        start = _segmented_cummax(a - rank * svc, is_start) + rank * svc
        # The counter's last arriver is its latest-serviced request; the
        # fetched value travels back at the counter's access latency.
        last = jax.ops.segment_max(start, gs, num_segments=w)
        done = last + table.latencies[i][jnp.minimum(idx, width - 1)]
        # Survivors run the compare/branch + counter-reset + next-level
        # setup, then compact into the next (shrunken) window.
        m = m // g
        w_next = min(int(widths[i + 1]), w)
        ready = jnp.where(jnp.arange(w_next) < m,
                          done[:w_next] + table.instr_cycles[i], jnp.inf)

    exit_time = ready[0] + cfg.wakeup_cycles
    last_arrival = jnp.max(arrivals, axis=-1)
    mean_res = jnp.mean(exit_time[..., None] - arrivals, axis=-1)
    return BarrierResult(
        exit_time=exit_time,
        last_arrival=last_arrival,
        span_cycles=exit_time - last_arrival,
        mean_residency=mean_res,
        energy=episode_energy(table.energy_static, table.active_cycles,
                              table.idle_power, n, mean_res),
        completed=jnp.isfinite(exit_time),
        abandoned_pes=jnp.int32(0),
        timed_out_levels=jnp.int32(0),
    )


# ---------------------------------------------------------------------------
# Degradation-tolerant (robust) cores: timeout + quorum release.
# ---------------------------------------------------------------------------

def _timeout_rows(spec: FaultSpec, depth: int) -> jnp.ndarray:
    """Normalize a spec's timeout to a per-PADDED-level (depth,) row: a
    scalar broadcasts, a shorter row is tail-padded with ``+inf``.
    Padding levels are singleton pass-throughs under ANY timeout
    (``min(x, x + t) == x`` for ``t >= 0``), so the alignment only
    matters for the real levels."""
    t = jnp.asarray(spec.timeout_cycles, jnp.float32)
    if t.ndim == 0:
        return jnp.broadcast_to(t, (depth,))
    if t.shape[0] < depth:
        pad = jnp.full((depth - t.shape[0],), jnp.inf, jnp.float32)
        return jnp.concatenate([t, pad])
    return t[:depth]


def _group_rank(gs: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Service rank of each sorted request WITHIN its group.

    ``gs`` is the group-id column co-sorted with the per-bank service
    order, so within a group (one counter = one bank) increasing sorted
    position IS service order.  A stable sort of ``gs`` makes each
    group a contiguous run whose offset from its first occurrence is
    the rank; the co-sorted ``idx`` scatters ranks back to sorted
    positions."""
    g2, pos = jax.lax.sort((gs, idx), num_keys=1)
    rank = idx - jnp.searchsorted(g2, g2, side="left")
    return jnp.zeros_like(idx).at[pos].set(rank)


def _robust_release(start, gs, grank, g, q, tmo, num_segments):
    """Per-counter release algebra shared by both robust cores.

    Within-group service starts are nondecreasing in sorted order, so
    the K-th serviced child's start is the max over the first
    ``k = clip(ceil(q * g), 1, g)`` ranks; the watchdog deadline counts
    from the FIRST serviced child.  Returns per-group-slot
    ``(release, fired)``.  Degeneracy: ``q == 1`` masks nothing
    (``k == g``), ``tmo == +inf`` pushes the deadline to ``+inf``, and
    ``min(quorum_start, +inf)`` is the plain core's group max bit for
    bit."""
    gf = g.astype(jnp.float32)
    k = jnp.clip(jnp.ceil(q * gf), 1.0, gf)
    in_quorum = grank.astype(jnp.float32) < k
    qstart = jax.ops.segment_max(
        jnp.where(in_quorum, start, -jnp.inf), gs,
        num_segments=num_segments)
    fstart = -jax.ops.segment_max(-start, gs, num_segments=num_segments)
    deadline = fstart + tmo
    return jnp.minimum(qstart, deadline), deadline < qstart


def _robust_result(arrivals, ready, ok, cfg, n):
    """Final reductions shared by both robust cores: stats over the
    SURVIVING PEs.  Every op is a bitwise identity when nothing failed
    (``where`` with an all-true mask, ``max`` over the unmasked
    arrivals, ``mean * n/n``)."""
    exit_time = ready[0] + cfg.wakeup_cycles
    live0 = jnp.isfinite(arrivals)
    last_arrival = jnp.max(jnp.where(live0, arrivals, -jnp.inf), axis=-1)
    n_ok = jnp.sum(ok)
    abandoned = jnp.int32(n) - n_ok
    resid = jnp.mean(jnp.where(ok, exit_time[..., None] - arrivals, 0.0),
                     axis=-1)
    mean_res = resid * (jnp.float32(n)
                        / jnp.maximum(n_ok, 1).astype(jnp.float32))
    return exit_time, last_arrival, mean_res, abandoned


def _scan_robust_core(arrivals: jnp.ndarray, table: LevelTable,
                      cfg: TeraPoolConfig, widths: tuple | None = None,
                      spec: FaultSpec = None) -> BarrierResult:
    """:func:`_scan_core` with timeout/quorum release and per-PE
    completion tracking (see the module docstring's fault model).

    The level walk is identical until the counter releases: instead of
    waiting for its last child, each counter releases at
    ``min(kth_serviced_start, first_serviced_start + timeout)``.
    Children whose service start lies after their counter's release
    are *abandoned*: live lane ``l`` of a level with ``m`` live lanes
    represents the contiguous block of ``n // m`` original PEs (lane
    compaction preserves contiguity level over level), so the block is
    struck from the per-PE ``ok`` vector.  A fully-dead subtree whose
    own counter never released carries ``+inf`` upward and is abandoned
    at whichever ancestor does release.

    All fault knobs (mask-conditioned arrivals, timeout row, quorum
    fraction) are traced data: one compiled program covers every fault
    scenario over one cluster, exactly like the plain core.
    """
    n = arrivals.shape[-1]
    arrivals = jnp.asarray(arrivals, jnp.float32)
    idx = jnp.arange(n)
    width = table.bank_ids.shape[-1]
    depth = table.group_sizes.shape[-1]
    tmo_rows = _timeout_rows(spec, depth)
    q = jnp.asarray(spec.quorum_frac, jnp.float32)

    ready0 = arrivals + table.entry_instr
    ok0 = jnp.isfinite(arrivals)

    def step(carry, level):
        ready, m, ok, timed = carry
        g, lat_col, instr, bank_col, svc, tmo = level
        grp = idx // g
        bank = bank_col[jnp.minimum(grp, width - 1)]
        order = jnp.lexsort((ready, bank))
        a = ready[order]
        b = bank[order]
        gs = grp[order]
        is_start = jnp.concatenate(
            [jnp.ones((1,), bool), b[1:] != b[:-1]])
        seg_first = jax.lax.cummax(jnp.where(is_start, idx, 0))
        rank = (idx - seg_first).astype(jnp.float32)
        start = _segmented_cummax(a - rank * svc, is_start) + rank * svc
        grank = _group_rank(gs, idx)
        release, fired = _robust_release(start, gs, grank, g, q, tmo, n)
        done = release + lat_col[jnp.minimum(idx, width - 1)]
        # Strike the abandoned children's original-PE blocks.  Phantom
        # groups (all-+inf) never release finitely nor fire, so only
        # live groups contribute.
        ab_lane = jnp.zeros((n,), bool).at[order].set(start > release[gs])
        span = jnp.int32(n) // m
        ok = ok & ~ab_lane[idx // span]
        timed = timed + jnp.any(fired).astype(jnp.int32)
        m = m // g
        ready = jnp.where(idx < m, done + instr, jnp.inf)
        return (ready, m, ok, timed), None

    TRACE_COUNTS["scan_robust_core"] += 1
    levels = (table.group_sizes, table.latencies, table.instr_cycles,
              table.bank_ids, table.service_cycles, tmo_rows)
    (ready, _, ok, timed), _ = jax.lax.scan(
        step, (ready0, jnp.int32(n), ok0, jnp.int32(0)), levels)

    exit_time, last_arrival, mean_res, abandoned = _robust_result(
        arrivals, ready, ok, cfg, n)
    return BarrierResult(
        exit_time=exit_time,
        last_arrival=last_arrival,
        span_cycles=exit_time - last_arrival,
        mean_residency=mean_res,
        energy=robust_episode_energy(
            table.energy_static, table.active_cycles, table.idle_power,
            n, mean_res, spec.e_timeout_poll, timed.astype(jnp.float32),
            spec.e_abandon, abandoned.astype(jnp.float32)),
        completed=jnp.isfinite(exit_time),
        abandoned_pes=abandoned,
        timed_out_levels=timed,
    )


def _telescope_robust_core(arrivals: jnp.ndarray, table: LevelTable,
                           cfg: TeraPoolConfig,
                           widths: tuple | None = None,
                           spec: FaultSpec = None) -> BarrierResult:
    """:func:`_telescope_core` with timeout/quorum release — the same
    shrinking-width pyramid, the same release algebra as
    :func:`_scan_robust_core` (the two are bit-for-bit equal at every
    width table, like their plain twins).  The only extra per-step work
    is one stable sort for the within-group service rank and the
    abandonment scatter, both confined to the step's window."""
    n = arrivals.shape[-1]
    arrivals = jnp.asarray(arrivals, jnp.float32)
    width = table.bank_ids.shape[-1]
    depth = table.group_sizes.shape[-1]
    tmo_rows = _timeout_rows(spec, depth)
    q = jnp.asarray(spec.quorum_frac, jnp.float32)

    if widths is None:
        widths = default_widths(n, depth)
    if len(widths) != depth + 1:
        raise ValueError(
            f"widths table has {len(widths)} entries for a depth-"
            f"{depth} table; need depth + 1")

    TRACE_COUNTS["telescope_robust_core"] += 1

    ready = arrivals + table.entry_instr
    ok = jnp.isfinite(arrivals)
    timed = jnp.int32(0)
    idx_n = jnp.arange(n)
    m = jnp.int32(n)
    for i in range(depth):
        w = min(int(widths[i]), n)
        ready = ready[:w]
        idx = jnp.arange(w)
        g = table.group_sizes[i]
        svc = table.service_cycles[i]
        grp = idx // g
        bank = table.bank_ids[i][jnp.minimum(grp, width - 1)]
        b, a, gs, lane = jax.lax.sort((bank, ready, grp, idx), num_keys=2)
        first = jnp.searchsorted(b, b, side="left")
        rank = (idx - first).astype(jnp.float32)
        is_start = jnp.concatenate(
            [jnp.ones((1,), bool), b[1:] != b[:-1]])
        start = _segmented_cummax(a - rank * svc, is_start) + rank * svc
        grank = _group_rank(gs, idx)
        release, fired = _robust_release(start, gs, grank, g, q,
                                         tmo_rows[i], w)
        done = release + table.latencies[i][jnp.minimum(idx, width - 1)]
        ab_lane = jnp.zeros((w,), bool).at[lane].set(start > release[gs])
        span = jnp.int32(n) // m
        ok = ok & ~ab_lane[idx_n // span]
        timed = timed + jnp.any(fired).astype(jnp.int32)
        m = m // g
        w_next = min(int(widths[i + 1]), w)
        ready = jnp.where(jnp.arange(w_next) < m,
                          done[:w_next] + table.instr_cycles[i], jnp.inf)

    exit_time, last_arrival, mean_res, abandoned = _robust_result(
        arrivals, ready, ok, cfg, n)
    return BarrierResult(
        exit_time=exit_time,
        last_arrival=last_arrival,
        span_cycles=exit_time - last_arrival,
        mean_residency=mean_res,
        energy=robust_episode_energy(
            table.energy_static, table.active_cycles, table.idle_power,
            n, mean_res, spec.e_timeout_poll, timed.astype(jnp.float32),
            spec.e_abandon, abandoned.astype(jnp.float32)),
        completed=jnp.isfinite(exit_time),
        abandoned_pes=abandoned,
        timed_out_levels=timed,
    )


_CORE_FNS = {"scan": _scan_core, "telescope": _telescope_core}
_ROBUST_CORE_FNS = {"scan": _scan_robust_core,
                    "telescope": _telescope_robust_core}


def resolve_core(core: str | None = None) -> str:
    """Normalize a core selector (``"telescope"`` | ``"scan"`` |
    ``None`` for the session default) to a validated core name — the
    static-argument form every jitted entry point shares."""
    name = DEFAULT_CORE if core is None else core
    if name not in _CORE_FNS:
        raise ValueError(
            f"unknown simulator core {name!r}; choose from {CORES}")
    return name


def core_fn(core: str | None = None, *, robust: bool = False):
    """Resolve a core selector to its implementation (``robust=True``
    for the timeout/quorum fault-model variant)."""
    name = resolve_core(core)
    return _ROBUST_CORE_FNS[name] if robust else _CORE_FNS[name]


@partial(jax.jit, static_argnums=(2, 3, 4), donate_argnums=(0,))
def _simulate_flat(arrivals: jnp.ndarray, table: LevelTable,
                   cfg: TeraPoolConfig, core: str,
                   widths: tuple | None) -> BarrierResult:
    """Jitted (trials, n_pes) batch of the selected core.  The arrival
    block is donated: it is a flattened copy owned by
    :func:`simulate_table`, so its buffer can be reused in place on
    backends that support donation.  ``widths`` is the static
    telescope width table (``None`` = the conservative default)."""
    fn = core_fn(core)
    return jax.vmap(lambda a: fn(a, table, cfg, widths))(arrivals)


@partial(jax.jit, static_argnums=(2, 3, 4), donate_argnums=(0,))
def _simulate_flat_robust(arrivals: jnp.ndarray, table: LevelTable,
                          cfg: TeraPoolConfig, core: str,
                          widths: tuple | None,
                          spec: FaultSpec) -> BarrierResult:
    """Robust twin of :func:`_simulate_flat`.  The spec rides as a
    traced pytree argument: new timeouts / quorums / fault masks reuse
    the one compiled program."""
    fn = core_fn(core, robust=True)
    return jax.vmap(lambda a: fn(a, table, cfg, widths, spec))(arrivals)


def simulate_table(arrivals: jnp.ndarray, table: LevelTable,
                   cfg: TeraPoolConfig = DEFAULT, *,
                   core: str | None = None,
                   faults: FaultSpec | None = None,
                   fault_mask=None) -> BarrierResult:
    """Simulate directly from a padded :class:`LevelTable`.

    Accepts any leading batch shape on ``arrivals``; all batch entries
    run through one jitted, vmapped program.  ``core`` selects the
    simulator implementation (default :data:`DEFAULT_CORE`).

    ``faults`` switches to the degradation-tolerant cores
    (timeout/quorum release, see the module docstring);
    ``fault_mask`` fail-stops the masked PEs by setting their arrivals
    to ``+inf`` (any shape broadcastable against ``arrivals``).  Both
    are traced data — the fault path has its own single compiled
    program per (shape, core, widths).
    """
    if fault_mask is not None and faults is None:
        faults = fault_spec()
    # Light check (group-size column only): tables from level_table /
    # stack_tables were fully validated at construction; this guards
    # hand-built tables without a per-call host sync of the big
    # latency columns.
    table = validate_tail_padding(table, full=False)
    arrivals = jnp.asarray(arrivals, jnp.float32)
    if fault_mask is not None:
        arrivals = jnp.where(jnp.asarray(fault_mask, bool), jnp.inf,
                             arrivals)
    batch = arrivals.shape[:-1]
    widths = telescope_widths(table, arrivals.shape[-1])
    # jnp.copy guarantees _simulate_flat donates a private buffer, never
    # the caller's array (asarray/reshape can alias their input).
    flat = jnp.copy(arrivals.reshape((-1, arrivals.shape[-1])))
    with quiet_donation():
        if faults is None:
            res = _simulate_flat(flat, table, cfg, resolve_core(core),
                                 widths)
        else:
            res = _simulate_flat_robust(flat, table, cfg,
                                        resolve_core(core), widths, faults)
    return BarrierResult(*(x.reshape(batch) for x in res))


def simulate(arrivals: jnp.ndarray, schedule: BarrierSchedule,
             cfg: TeraPoolConfig = DEFAULT, *,
             placement=None, core: str | None = None,
             energy_model: EnergyModel = DEFAULT_ENERGY,
             faults: FaultSpec | None = None,
             fault_mask=None) -> BarrierResult:
    """Simulate one barrier episode (or a leading batch of them).

    Args:
      arrivals: (..., n_pes) per-PE barrier-entry cycles (float or int).
      schedule: static tree structure from :mod:`repro.core.barrier`.
      cfg: machine model.
      placement: optional :class:`~repro.core.placement.CounterPlacement`
        mapping every counter to a concrete bank; ``None`` uses the
        legacy span-heuristic latencies with conflict-free banks.
      core: simulator implementation, ``"telescope"`` (default) or
        ``"scan"`` (the bit-for-bit oracle core).
      energy_model: per-event cost model pricing the ``energy`` column
        (:mod:`repro.core.energy`).
      faults: optional :class:`~repro.core.barrier.FaultSpec` enabling
        timeout/quorum release semantics (the degradation-tolerant
        cores).
      fault_mask: optional per-PE bool mask (broadcastable against
        ``arrivals``); masked PEs fail-stop (arrival ``+inf``).

    Returns:
      :class:`BarrierResult` with the leading batch shape of ``arrivals``.
    """
    arrivals = jnp.asarray(arrivals, jnp.float32)
    if arrivals.shape[-1] != schedule.n_pes:
        raise ValueError(
            f"arrivals has {arrivals.shape[-1]} PEs, schedule expects "
            f"{schedule.n_pes}")
    table = level_table(schedule, cfg=cfg, placement=placement,
                        energy_model=energy_model)
    return simulate_table(arrivals, table, cfg, core=core, faults=faults,
                          fault_mask=fault_mask)


def simulate_reference(arrivals: jnp.ndarray, schedule: BarrierSchedule,
                       cfg: TeraPoolConfig = DEFAULT,
                       energy_model: EnergyModel = DEFAULT_ENERGY
                       ) -> BarrierResult:
    """The seed per-level Python loop, kept as the equivalence oracle.

    Retraces per schedule (shape-changing reshapes); use only in tests
    and spot checks.
    """
    arrivals = jnp.asarray(arrivals, jnp.float32)
    if arrivals.shape[-1] != schedule.n_pes:
        raise ValueError(
            f"arrivals has {arrivals.shape[-1]} PEs, schedule expects "
            f"{schedule.n_pes}")

    # The hardware event unit replaces the software level path: one
    # trigger store on entry, parallel (unserialized) stage
    # aggregation, zero per-level bookkeeping.
    hw = schedule.hw
    entry = cfg.hw_entry_instr if hw else cfg.instr_per_level
    instr = 0 if hw else cfg.instr_per_level
    svc = 0 if hw else None

    # Ready time of the survivors entering the current level.  Level 0:
    # every PE, offset by the per-level software path (call, address
    # computation, atomic issue).
    ready = arrivals + entry
    for lvl in schedule.levels:
        grouped = ready.reshape(ready.shape[:-1] + (-1, lvl.group_size))
        done = _serialize_group(grouped, lvl.latency, cfg, svc=svc)
        # Survivors run the compare/branch + counter-reset + next-level
        # setup before issuing the next atomic.
        ready = done + instr

    # ``ready`` is now (..., 1): the final survivor after its bookkeeping.
    final = ready[..., 0]
    exit_time = final + cfg.wakeup_cycles
    last_arrival = jnp.max(arrivals, axis=-1)
    mean_res = jnp.mean(exit_time[..., None] - arrivals, axis=-1)
    stat, act, idle = schedule_energy_constants(
        schedule, None, cfg, energy_model)
    zeros = jnp.zeros(exit_time.shape, jnp.int32)
    return BarrierResult(
        exit_time=exit_time,
        last_arrival=last_arrival,
        span_cycles=exit_time - last_arrival,
        mean_residency=mean_res,
        energy=episode_energy(jnp.float32(stat), jnp.float32(act),
                              jnp.float32(idle), schedule.n_pes, mean_res),
        completed=jnp.isfinite(exit_time),
        abandoned_pes=zeros,
        timed_out_levels=zeros,
    )


# ---------------------------------------------------------------------------
# Independent numpy fault oracle (test-only).
# ---------------------------------------------------------------------------

def _oracle_rows(schedule: BarrierSchedule, placement) -> list:
    """Per level: ``(group_size, bank ids per counter, latency per
    counter)`` — derived straight from the schedule/placement, not from
    any LevelTable, so the oracle shares no table-building code with
    the cores.  Without a placement every counter gets a distinct bank
    (the conflict-free default) at its level's span-heuristic
    latency."""
    rows = []
    m = schedule.n_pes
    for li, lvl in enumerate(schedule.levels):
        count = m // lvl.group_size
        if placement is not None:
            banks = np.asarray(placement.banks[li][:count], np.int64)
            lats = np.asarray(placement.latencies[li][:count], np.float32)
        else:
            banks = np.arange(count, dtype=np.int64)
            lats = np.full(count, np.float32(lvl.latency), np.float32)
        rows.append((lvl.group_size, banks, lats))
        m = count
    return rows


def _robust_episode(arr: np.ndarray, rows: list, cfg: TeraPoolConfig,
                    hw: bool, timeout_row: np.ndarray, q: float) -> tuple:
    """One degradation-tolerant episode as an explicit numpy walk:
    per-bank FIFO queues served at the bank interval, per-counter
    quorum/timeout release, per-PE abandonment bookkeeping.  Float32
    op-for-op the sequence of the robust cores, but organized as
    per-bank/per-counter loops rather than segmented scans."""
    f32 = np.float32
    n = arr.size
    entry = f32(cfg.hw_entry_instr if hw else cfg.instr_per_level)
    svc = f32(0.0 if hw else cfg.bank_service_cycles)
    instr = f32(0.0 if hw else cfg.instr_per_level)
    ready = arr.astype(f32) + entry
    ok = np.isfinite(arr)
    timed = 0
    m = n
    for li, (g, banks, lats) in enumerate(rows):
        tmo = f32(timeout_row[li])
        n_grp = m // g
        grp = np.arange(m) // g
        bank = banks[grp]
        order = np.lexsort((ready, bank))   # stable: (bank, ready, index)
        a = ready[order]
        b = bank[order]
        gs = grp[order]
        # Per-bank FIFO: within a bank run, max-plus service starts.
        start = np.empty(m, f32)
        pos = 0
        while pos < m:
            end = pos
            while end < m and b[end] == b[pos]:
                end += 1
            r = np.arange(end - pos, dtype=f32) * svc
            start[pos:end] = np.maximum.accumulate(a[pos:end] - r) + r
            pos = end
        # K-of-g quorum: ceil in f32 exactly as the cores compute it.
        k = int(min(max(float(np.ceil(f32(q) * f32(g))), 1.0), float(g)))
        done = np.empty(n_grp, f32)
        ab_lane = np.zeros(m, bool)
        level_fired = False
        for j in range(n_grp):
            sel = np.where(gs == j)[0]      # increasing = service order
            s_g = start[sel]
            qstart = f32(np.max(s_g[:k]))
            fstart = f32(np.min(s_g))
            deadline = f32(fstart + tmo)
            release = min(qstart, deadline)
            if deadline < qstart:
                level_fired = True
            done[j] = f32(release + f32(lats[j]))
            ab_lane[order[sel[s_g > release]]] = True
        span = n // m
        for lane in np.nonzero(ab_lane)[0]:
            ok[lane * span:(lane + 1) * span] = False
        timed += int(level_fired)
        ready = done + instr
        m = n_grp
    exit_time = f32(ready[0] + f32(cfg.wakeup_cycles))
    return exit_time, ok, timed


def simulate_robust_reference(arrivals, schedule: BarrierSchedule,
                              cfg: TeraPoolConfig = DEFAULT, *,
                              placement=None,
                              faults: FaultSpec | None = None,
                              fault_mask=None,
                              energy_model: EnergyModel = DEFAULT_ENERGY
                              ) -> BarrierResult:
    """Independent numpy oracle for the degradation-tolerant cores:
    explicit per-bank queues, per-counter quorum/timeout release and
    per-PE abandonment, for one episode or a leading batch.  The final
    reductions mirror the cores' jnp ops (same values in, same float32
    ops out) and the energy rides the shared jitted
    :func:`repro.core.energy.robust_episode_energy`, so agreement is
    bit-for-bit.  Pure python loops — test-only."""
    if faults is None:
        faults = fault_spec()
    arr = np.asarray(arrivals, np.float32)
    if arr.shape[-1] != schedule.n_pes:
        raise ValueError(
            f"arrivals has {arr.shape[-1]} PEs, schedule expects "
            f"{schedule.n_pes}")
    if fault_mask is not None:
        arr = np.where(np.broadcast_to(np.asarray(fault_mask, bool),
                                       arr.shape), np.float32(np.inf), arr)
    n = schedule.n_pes
    batch = arr.shape[:-1]
    flat = arr.reshape((-1, n))

    hw = bool(getattr(schedule, "hw", False))
    if hw and placement is not None:
        raise ValueError(
            "hardware event-unit barriers have no counters to place")
    rows = _oracle_rows(schedule, placement)
    t = np.asarray(faults.timeout_cycles, np.float32)
    depth = len(schedule.levels)
    if t.ndim == 0:
        timeout_row = np.full(depth, t, np.float32)
    else:
        timeout_row = np.full(depth, np.inf, np.float32)
        timeout_row[:min(depth, t.shape[0])] = t[:depth]
    q = float(np.float32(faults.quorum_frac))

    walks = [_robust_episode(a, rows, cfg, hw, timeout_row, q)
             for a in flat]
    exits = jnp.asarray(np.asarray([w[0] for w in walks], np.float32))
    oks = jnp.asarray(np.stack([w[1] for w in walks]))
    timed = jnp.asarray(np.asarray([w[2] for w in walks], np.int32))

    arr_j = jnp.asarray(flat)
    live0 = jnp.isfinite(arr_j)
    last = jnp.max(jnp.where(live0, arr_j, -jnp.inf), axis=-1)
    n_ok = jnp.sum(oks, axis=-1)
    abandoned = jnp.int32(n) - n_ok
    resid = jnp.mean(jnp.where(oks, exits[:, None] - arr_j, 0.0), axis=-1)
    mean_res = resid * (jnp.float32(n)
                        / jnp.maximum(n_ok, 1).astype(jnp.float32))
    stat, act, idle = schedule_energy_constants(
        schedule, placement, cfg, energy_model)
    energy = robust_episode_energy(
        jnp.float32(stat), jnp.float32(act), jnp.float32(idle), n,
        mean_res, jnp.asarray(faults.e_timeout_poll, jnp.float32),
        timed.astype(jnp.float32),
        jnp.asarray(faults.e_abandon, jnp.float32),
        abandoned.astype(jnp.float32))
    return BarrierResult(
        exit_time=exits.reshape(batch),
        last_arrival=last.reshape(batch),
        span_cycles=(exits - last).reshape(batch),
        mean_residency=mean_res.reshape(batch),
        energy=jnp.asarray(energy).reshape(batch),
        completed=jnp.isfinite(exits).reshape(batch),
        abandoned_pes=abandoned.reshape(batch),
        timed_out_levels=timed.reshape(batch),
    )


def uniform_arrivals(key: jax.Array, max_delay: float, n_pes: int,
                     n_trials: int = 16) -> jnp.ndarray:
    """The paper's synthetic benchmark (Sec. 4.1): per-PE delay drawn
    uniformly from [0, max_delay]."""
    if max_delay <= 0:
        return jnp.zeros((n_trials, n_pes), jnp.float32)
    return jax.random.uniform(key, (n_trials, n_pes), jnp.float32,
                              0.0, max_delay)


def mean_span_cycles(key: jax.Array, schedule: BarrierSchedule,
                     max_delay: float, cfg: TeraPoolConfig = DEFAULT,
                     n_trials: int = 16) -> jnp.ndarray:
    """Average Fig. 4a metric (last-in -> last-out cycles) over trials."""
    arr = uniform_arrivals(key, max_delay, schedule.n_pes, n_trials)
    return jnp.mean(simulate(arr, schedule, cfg).span_cycles)


def overhead_fraction(key: jax.Array, schedule: BarrierSchedule,
                      sfr_cycles: float, max_delay: float,
                      cfg: TeraPoolConfig = DEFAULT,
                      n_trials: int = 16) -> jnp.ndarray:
    """Fig. 4b metric: mean per-PE barrier residency over total runtime,
    as a function of the synchronization-free region (SFR)."""
    arr = uniform_arrivals(key, max_delay, schedule.n_pes, n_trials)
    res = simulate(arr, schedule, cfg)
    barrier = jnp.mean(res.mean_residency)
    return barrier / (sfr_cycles + barrier)
