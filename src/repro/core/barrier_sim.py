"""Cycle-level simulator of TeraPool barrier synchronization.

Given per-PE *arrival times* (the cycle at which each PE calls the
barrier), computes the exact timing of the arrival tree under the
machine model of :mod:`repro.core.topology`:

* every PE issues an atomic fetch&add to its group's counter;
* concurrent atomics to one counter serialize at 1/cycle (single-ported
  bank) — modelled exactly with a max-plus prefix scan;
* the group's last arriver observes ``group_size - 1``, resets the
  counter and proceeds to the next level (re-initialization is folded
  into arrival);
* the final survivor writes the memory-mapped wakeup register; the
  wakeup unit raises the hardwired lines and all sleeping PEs resume
  from WFI simultaneously.

Everything is pure JAX, fully vectorized over groups, and `vmap`-able
over Monte-Carlo trials.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .barrier import BarrierSchedule
from .topology import DEFAULT, TeraPoolConfig


class BarrierResult(NamedTuple):
    """Timing of one barrier episode (all in cycles)."""

    exit_time: jnp.ndarray        # scalar: cycle at which every PE resumes
    last_arrival: jnp.ndarray     # scalar: cycle the last PE entered
    span_cycles: jnp.ndarray      # exit_time - last_arrival  (Fig. 4a metric)
    mean_residency: jnp.ndarray   # mean over PEs of (exit - own arrival)


def _serialize_group(ready: jnp.ndarray, latency: int,
                     cfg: TeraPoolConfig) -> jnp.ndarray:
    """Serialize atomics within each group (rows of ``ready``).

    ``ready[g, j]`` is the cycle PE j of group g issues its atomic.  The
    bank services one request per ``bank_service_cycles``; requests are
    served in arrival order.  Returns the completion time of the *last*
    request per group, i.e. when the last arriver has its fetched value.

    With sorted issue times a_(1..k), service start of the j-th request is
        s_j = max_{i<=j} ( a_i + (j - i) * svc )
            = j*svc + cummax( a_j - j*svc )
    — a max-plus prefix scan, fully vectorized.
    """
    svc = cfg.bank_service_cycles
    a = jnp.sort(ready, axis=-1)
    j = jnp.arange(a.shape[-1], dtype=a.dtype) * svc
    start = jax.lax.cummax(a - j, axis=a.ndim - 1) + j
    # The response of the final request travels back to the last arriver.
    return start[..., -1] + latency


def simulate(arrivals: jnp.ndarray, schedule: BarrierSchedule,
             cfg: TeraPoolConfig = DEFAULT) -> BarrierResult:
    """Simulate one barrier episode.

    Args:
      arrivals: (n_pes,) per-PE barrier-entry cycles (float or int).
      schedule: static tree structure from :mod:`repro.core.barrier`.
      cfg: machine model.

    Returns:
      :class:`BarrierResult`.
    """
    arrivals = jnp.asarray(arrivals, jnp.float32)
    if arrivals.shape[-1] != schedule.n_pes:
        raise ValueError(
            f"arrivals has {arrivals.shape[-1]} PEs, schedule expects "
            f"{schedule.n_pes}")

    # Ready time of the survivors entering the current level.  Level 0:
    # every PE, offset by the per-level software path (call, address
    # computation, atomic issue).
    ready = arrivals + cfg.instr_per_level
    for lvl in schedule.levels:
        grouped = ready.reshape(ready.shape[:-1] + (-1, lvl.group_size))
        done = _serialize_group(grouped, lvl.latency, cfg)
        # Survivors run the compare/branch + counter-reset + next-level
        # setup before issuing the next atomic.
        ready = done + cfg.instr_per_level

    # ``ready`` is now (..., 1): the final survivor after its bookkeeping.
    final = ready[..., 0]
    exit_time = final + cfg.wakeup_cycles
    last_arrival = jnp.max(arrivals, axis=-1)
    return BarrierResult(
        exit_time=exit_time,
        last_arrival=last_arrival,
        span_cycles=exit_time - last_arrival,
        mean_residency=jnp.mean(exit_time[..., None] - arrivals, axis=-1),
    )


def simulate_batch(arrivals: jnp.ndarray, schedule: BarrierSchedule,
                   cfg: TeraPoolConfig = DEFAULT) -> BarrierResult:
    """vmap of :func:`simulate` over a leading Monte-Carlo axis."""
    return jax.vmap(lambda a: simulate(a, schedule, cfg))(arrivals)


def uniform_arrivals(key: jax.Array, max_delay: float, n_pes: int,
                     n_trials: int = 16) -> jnp.ndarray:
    """The paper's synthetic benchmark (Sec. 4.1): per-PE delay drawn
    uniformly from [0, max_delay]."""
    if max_delay <= 0:
        return jnp.zeros((n_trials, n_pes), jnp.float32)
    return jax.random.uniform(key, (n_trials, n_pes), jnp.float32,
                              0.0, max_delay)


def mean_span_cycles(key: jax.Array, schedule: BarrierSchedule,
                     max_delay: float, cfg: TeraPoolConfig = DEFAULT,
                     n_trials: int = 16) -> jnp.ndarray:
    """Average Fig. 4a metric (last-in -> last-out cycles) over trials."""
    arr = uniform_arrivals(key, max_delay, schedule.n_pes, n_trials)
    return jnp.mean(simulate_batch(arr, schedule, cfg).span_cycles)


def overhead_fraction(key: jax.Array, schedule: BarrierSchedule,
                      sfr_cycles: float, max_delay: float,
                      cfg: TeraPoolConfig = DEFAULT,
                      n_trials: int = 16) -> jnp.ndarray:
    """Fig. 4b metric: mean per-PE barrier residency over total runtime,
    as a function of the synchronization-free region (SFR)."""
    arr = uniform_arrivals(key, max_delay, schedule.n_pes, n_trials)
    res = simulate_batch(arr, schedule, cfg)
    barrier = jnp.mean(res.mean_residency)
    return barrier / (sfr_cycles + barrier)
