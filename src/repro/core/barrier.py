"""Barrier schedules: mixed-radix trees and their algebra.

A *schedule* is the static structure of the arrival tree (Sec. 3 of the
paper): how many PEs synchronize per shared counter at every level, and
the locality class (hence latency) of each level's counters.

The primitive is :func:`mixed_radix_tree`: an arbitrary per-level
composition of group sizes whose product covers the cluster.  Every
named schedule is a point in that space:

  * ``central_counter``      -> one level of size N,
  * ``kary_tree(k)``         -> ``[first, k, k, ..., k]`` (the paper
    adapts the *first* level when ``log_k(N)`` is not an integer),
  * hierarchy-matched trees  -> e.g. ``(8, 16, 8)`` for TeraPool's
    Tile/Group/Cluster structure — the tuned design points of Sec. 5
    that beat the best uniform radix (see :mod:`repro.core.tuning`).

Schedules compose (:func:`compose`): a tree over one Tile stacked under
a tree over the Groups is again a mixed-radix tree, with spans and
latencies re-derived for the combined hierarchy.

Partial barriers synchronize a contiguous subset of the cluster (e.g. the
256 PEs sharing one FFT) using the per-Group / per-Tile wakeup registers.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import List, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .energy import DEFAULT_ENERGY, EnergyModel, schedule_energy_constants
from .topology import DEFAULT, TeraPoolConfig


@dataclasses.dataclass(frozen=True)
class Level:
    """One level of the arrival tree."""

    group_size: int   # PEs (survivors) sharing one counter at this level
    span: int         # contiguous original-PE span covered by one group
    latency: int      # access latency to this level's counters (cycles)


@dataclasses.dataclass(frozen=True)
class BarrierSchedule:
    """Static structure of one barrier instance.

    ``radix`` is the uniform radix for k-ary trees and ``0`` for a
    genuinely mixed-radix composition (no single k describes it).

    ``hw`` marks a hardware event-unit barrier
    (:func:`hw_event_unit`): the levels describe the unit's
    aggregation stages (combinational, no shared-counter atomics, no
    per-level software path) instead of counter tree levels.
    """

    n_pes: int                 # PEs synchronized by this barrier
    radix: int
    levels: tuple              # tuple[Level, ...]
    partial: bool = False      # True if a subset-of-cluster barrier
    hw: bool = False           # True if a hardware event-unit barrier

    @property
    def n_levels(self) -> int:
        return len(self.levels)

    @property
    def sizes(self) -> tuple:
        """Per-level group sizes, leaf level first."""
        return tuple(lvl.group_size for lvl in self.levels)

    @property
    def name(self) -> str:
        """Canonical name: group sizes joined leaf-to-root, e.g.
        ``"8x16x8"`` (plus a ``p`` suffix for partial barriers)."""
        return schedule_name(self)


def _check_pow2(x: int, name: str) -> None:
    if x < 2 or (x & (x - 1)) != 0:
        raise ValueError(f"{name} must be a power of two >= 2, got {x}")


def _check_size(x: int, name: str) -> None:
    """Level sizes are any integer >= 2: non-power-of-two clusters
    (768-PE / 12-Tile, asymmetric multi-cluster shapes) factor into
    levels like 3 or 12 that the generalized telescope widths handle
    exactly (:func:`telescope_widths`)."""
    if x < 2:
        raise ValueError(f"{name} must be an integer >= 2, got {x}")


def mixed_radix_tree(sizes: Sequence[int], n_pes: int | None = None,
                     cfg: TeraPoolConfig = DEFAULT, *,
                     partial: bool = False) -> BarrierSchedule:
    """Build the arrival tree with per-level group ``sizes`` (leaf level
    first).  The whole schedule design space in one constructor: every
    ordered factorization of ``N`` into level sizes >= 2 is a valid
    tree — all uniform radices, the hierarchy-matched compositions
    (e.g. ``(8, 16, 8)`` = Tile/Group/Cluster), non-power-of-two
    factors (``(8, 12, 8)`` for a 768-PE / 12-Tile cluster) and
    hierarchical multi-cluster stacks (``(8, 16, 8, 4)`` = intra tree
    x inter-cluster tree).

    Per-level spans are cumulative products of the sizes; each level's
    counter latency follows from the locality class of its span
    (``cfg.access_latency``), exactly as for uniform trees.
    """
    sizes = tuple(int(g) for g in sizes)
    if not sizes:
        raise ValueError("schedule needs at least one level")
    for g in sizes:
        _check_size(g, "level size")
    n = math.prod(sizes)
    if n_pes is not None and int(n_pes) != n:
        raise ValueError(
            f"level sizes {sizes} cover {n} PEs, expected {n_pes}")
    if n > cfg.n_pes:
        raise ValueError(f"schedule spans {n} PEs, cluster has {cfg.n_pes}")

    levels: List[Level] = []
    span = 1
    for g in sizes:
        span *= g
        levels.append(Level(group_size=g, span=span,
                            latency=cfg.access_latency(span)))

    # A single uniform k describes the tree iff every level past the
    # first is the same size k and the (possibly adapted) first level is
    # no larger — the exact shape kary_tree produces.
    tail = sizes[-1]
    uniform = all(g == tail for g in sizes[1:]) and sizes[0] <= tail
    return BarrierSchedule(n_pes=n, radix=tail if uniform else 0,
                           levels=tuple(levels), partial=partial)


def kary_tree(radix: int, n_pes: int | None = None,
              cfg: TeraPoolConfig = DEFAULT, *,
              partial: bool = False) -> BarrierSchedule:
    """The uniform-radix arrival tree for ``n_pes`` cores.

    The tail levels are exactly radix-k — ``e`` of them, where ``e`` is
    the largest exponent with ``k**e`` dividing ``N`` — and the first
    level synchronizes the leftover ``N / k**e`` PEs (paper Sec. 3:
    "adapted ... by synchronizing a number of PEs different from the
    radix of the tree in the first step").  For power-of-two ``N`` this
    reproduces the classic ``ceil(log_k N)``-level shape bit-for-bit;
    for non-power-of-two ``N`` (e.g. 768) the odd factor lands in the
    adapted first level (``768 = 3 x 4^4`` for ``k = 4``).
    """
    n = int(n_pes if n_pes is not None else cfg.n_pes)
    k = int(radix)
    _check_size(n, "n_pes")
    _check_size(k, "radix")
    if k > n:
        raise ValueError(f"radix {k} exceeds n_pes {n}")

    e = 0
    while n % (k ** (e + 1)) == 0:
        e += 1
    if e == 0:
        raise ValueError(f"radix {k} does not divide n_pes {n}")
    first = n // (k ** e)
    sizes: List[int] = ([k] * e if first == 1 else [first] + [k] * e)
    return mixed_radix_tree(sizes, n_pes=n, cfg=cfg, partial=partial)


def central_counter(n_pes: int | None = None,
                    cfg: TeraPoolConfig = DEFAULT) -> BarrierSchedule:
    """Linear central-counter barrier: every PE hits one shared counter."""
    n = int(n_pes if n_pes is not None else cfg.n_pes)
    return mixed_radix_tree((n,), cfg=cfg)


def partial_barrier(group_pes: int, radix: int,
                    cfg: TeraPoolConfig = DEFAULT) -> BarrierSchedule:
    """Barrier over a contiguous subset of ``group_pes`` cores (uses the
    selective Group/Tile wakeup registers of Fig. 1b)."""
    if group_pes > cfg.n_pes:
        raise ValueError("partial barrier larger than the cluster")
    return kary_tree(radix, n_pes=group_pes, cfg=cfg, partial=True)


def _hw_segments(n: int, cfg: TeraPoolConfig) -> tuple:
    """Aggregation-stage sizes of the event unit over ``n`` PEs: the
    physical Tile / Group / cluster fan-in hierarchy, greedily factored
    so non-power-of-two counts (768, 1536, asymmetric multi-cluster
    shapes) still cover ``n`` exactly — any leftover factor becomes one
    final stage."""
    dims = [cfg.pes_per_tile, cfg.tiles_per_group, cfg.n_groups]
    if getattr(cfg, "n_clusters", 1) > 1:
        dims.append(cfg.n_clusters)
    rem = int(n)
    segs: List[int] = []
    for d in dims:
        g = math.gcd(rem, d)
        if g > 1:
            segs.append(g)
            rem //= g
    if rem > 1:
        segs.append(rem)
    return tuple(segs) if segs else (1,)


def hw_event_unit(n_pes: int | None = None,
                  cfg: TeraPoolConfig = DEFAULT) -> BarrierSchedule:
    """The hardware synchronization/event-unit barrier of Glaser et al.
    (arXiv 2004.06662), as a schedule next to the software trees.

    Each PE signals arrival with ONE store to the unit's trigger
    register (``cfg.hw_entry_instr`` cycles of software — no counter
    atomics, no polling); the unit's combinational aggregation tree
    resolves a stage per ``cfg.hw_level_cycles`` (a stage spanning
    multiple clusters pays ``lat_remote`` instead), and the root fires
    the broadcast wakeup lines, resuming every WFI-slept core at once.
    Stages follow the physical Tile/Group/cluster fan-in
    (:func:`_hw_segments`), so the schedule algebra, level tables and
    both simulator cores treat it exactly like any other schedule —
    with zero per-level software overhead and no bank serialization.
    """
    n = int(n_pes if n_pes is not None else cfg.n_pes)
    _check_size(n, "n_pes")
    if n > cfg.n_pes:
        raise ValueError(f"schedule spans {n} PEs, cluster has {cfg.n_pes}")
    levels: List[Level] = []
    span = 1
    for g in _hw_segments(n, cfg):
        span *= g
        levels.append(Level(group_size=g, span=span,
                            latency=cfg.hw_stage_latency(span)))
    return BarrierSchedule(n_pes=n, radix=0, levels=tuple(levels), hw=True)


def all_radices(n_pes: int | None = None,
                cfg: TeraPoolConfig = DEFAULT) -> Sequence[int]:
    """Every valid uniform radix: the divisors >= 2 of ``N`` (for
    power-of-two ``N`` this is exactly the powers of two 2..N;
    ``k == N`` is the central counter)."""
    n = int(n_pes if n_pes is not None else cfg.n_pes)
    return [k for k in range(2, n + 1) if n % k == 0]


# ---------------------------------------------------------------------------
# Schedule algebra.
# ---------------------------------------------------------------------------

def compose(*schedules: BarrierSchedule,
            cfg: TeraPoolConfig = DEFAULT,
            partial: bool = False) -> BarrierSchedule:
    """Stack schedules leaf-to-root into one tree over the product of
    their PE counts.

    ``compose(tile, groups)`` synchronizes ``tile.n_pes`` PEs per leaf
    subtree, then the survivors through ``groups``: the level sizes
    concatenate, and spans/latencies are re-derived for the combined
    hierarchy (an outer level's counters move up a locality class once
    its span crosses a Tile or Group boundary).
    """
    if not schedules:
        raise ValueError("compose needs at least one schedule")
    sizes: List[int] = []
    for s in schedules:
        sizes.extend(lvl.group_size for lvl in s.levels)
    return mixed_radix_tree(sizes, cfg=cfg, partial=partial)


def schedule_name(schedule: BarrierSchedule, placement=None) -> str:
    """Canonical, sortable name: level sizes joined leaf-to-root
    (``"8x16x8"``), with a ``p`` suffix for partial barriers and an
    ``@strategy`` suffix when a counter placement is attached (e.g.
    ``"8x16x8@leaf_local"``) — the one label format every sweep result
    and 5G report uses."""
    base = "x".join(str(g) for g in schedule.sizes)
    base = ("hw" + base) if schedule.hw else base
    base += "p" if schedule.partial else ""
    return base + (f"@{placement.strategy}" if placement else "")


def describe(schedule: BarrierSchedule) -> str:
    """One-line human description of a schedule's structure."""
    kind = ("hardware event unit" if schedule.hw
            else f"central counter" if schedule.n_levels == 1
            and schedule.levels[0].group_size == schedule.n_pes
            else f"radix-{schedule.radix} tree" if schedule.radix
            else "mixed-radix tree")
    spans = ",".join(str(lvl.span) for lvl in schedule.levels)
    lats = ",".join(str(lvl.latency) for lvl in schedule.levels)
    part = " (partial)" if schedule.partial else ""
    return (f"{schedule_name(schedule)}: {kind} over {schedule.n_pes} "
            f"PEs{part}, spans [{spans}], latencies [{lats}]")


# ---------------------------------------------------------------------------
# Padded level tables: a dense, fixed-shape encoding of any schedule.
# ---------------------------------------------------------------------------

class LevelTable(NamedTuple):
    """Dense, fixed-shape encoding of a :class:`BarrierSchedule` (and
    optionally of WHERE its counters live).

    Every tree over ``n_pes`` cores fits in ``log2(n_pes)`` levels (the
    radix-2 depth), so padding each table to that depth gives every
    schedule of a given cluster size the *same array shapes*: the
    simulator compiles once and sweeps radices as data.  Padding levels
    are the identity — ``group_size == 1`` (each survivor alone at its
    counter), zero latency and zero software overhead — so they pass
    timings through unchanged.

    ``latencies`` and ``bank_ids`` are per-COUNTER columns of width
    ``G = counter_width(n_pes)`` (the most counters any level can
    have): counter ``j`` of a level reads column ``j``.  Without an
    explicit :class:`~repro.core.placement.CounterPlacement` the
    columns encode the paper's leaf-local policy — the span-heuristic
    latency broadcast per level, and one distinct bank per counter —
    so the default tables reproduce the pre-placement model
    bit-for-bit.  Sibling counters mapped to the SAME bank id contend:
    the scanned core serializes atomics per bank, not per counter.

    ``service_cycles`` and ``entry_instr`` make the *primitive* itself
    table data: software trees carry the bank service interval and the
    barrier-entry instruction path, the hardware event unit
    (:func:`hw_event_unit`) carries zeros and its trigger-store cost —
    with a zero service interval the per-bank max-plus scan degenerates
    to the plain group max, i.e. parallel single-cycle aggregation, so
    hardware and software barriers share one compiled program.

    ``energy_static`` / ``active_cycles`` / ``idle_power`` are the
    per-episode energy scalars of :func:`repro.core.energy.
    schedule_energy_constants`; the cores combine them with the
    episode's mean residency (:func:`repro.core.energy.episode_energy`)
    so the energy column is traced data too — a different
    :class:`~repro.core.energy.EnergyModel` never recompiles anything.

    Being a NamedTuple of arrays, a table is a JAX pytree: it can be
    ``vmap``-ed over a stacked leading axis (see :func:`stack_tables`)
    and fed straight through ``lax.scan``.
    """

    group_sizes: jnp.ndarray    # (L,) int32, 1 past the real depth
    latencies: jnp.ndarray      # (L, G) float32 per counter, 0 past depth
    instr_cycles: jnp.ndarray   # (L,) float32, 0 past the real depth
    bank_ids: jnp.ndarray       # (L, G) int32 counter -> bank, distinct
                                # identity banks past the real depth
    service_cycles: jnp.ndarray  # (L,) float32 bank service interval,
                                 # 0 for hw stages and padding
    entry_instr: jnp.ndarray    # () float32 barrier-entry software path
    energy_static: jnp.ndarray  # () float32 pJ, arrival-independent
    active_cycles: jnp.ndarray  # () float32 episode instruction cycles
    idle_power: jnp.ndarray     # () float32 pJ per idle PE-cycle

    @property
    def max_levels(self) -> int:
        return self.group_sizes.shape[-1]

    @property
    def max_counters(self) -> int:
        return self.bank_ids.shape[-1]


def validate_tail_padding(table: LevelTable, *,
                          full: bool = True) -> LevelTable:
    """Assert the canonical-table invariant: identity padding (group
    size 1, zero latency, zero software overhead) appears only as a
    contiguous TAIL after the real levels.

    The telescoping simulator core's ``N / 2**i`` survivor bound relies
    on exactly this: every level before the padding tail has group size
    >= 2, so the live count at least halves per step, and once padding
    starts only the single final survivor remains.  Tables built by
    :func:`level_table` / :func:`stack_tables` satisfy it by
    construction; hand-built tables are checked here (concrete arrays
    only — traced tables inside a jit are passed through unchecked).

    ``full=False`` checks the group-size column only (the part the
    width bound depends on) and skips the per-counter latency/instr
    columns — the cheap per-call guard ``simulate_table`` applies to
    tables it did not build itself.

    The check covers power-of-two AND non-power-of-two schedules alike
    (the survivor bound is cumulative-quotient based, not ``N / 2**i``;
    see :func:`telescope_widths`), and error messages name the
    offending table row, level index and group size so a bad entry in
    a big stacked sweep is locatable directly.

    Returns the table unchanged, for call-site chaining.
    """
    if isinstance(table.group_sizes, jax.core.Tracer):
        return table
    depth = table.group_sizes.shape[-1]
    sizes = np.asarray(table.group_sizes).reshape((-1, depth))
    pad = sizes == 1
    # padding must be a suffix: no real level (g >= 2) after a g == 1
    bad = pad[:, :-1] & ~pad[:, 1:]
    if np.any(bad):
        row, lvl = (int(x) for x in np.argwhere(bad)[0])
        raise ValueError(
            f"level table row {row} has identity padding (group size 1) "
            f"at level {lvl} before a real level {lvl + 1} (group size "
            f"{int(sizes[row, lvl + 1])}); canonical tables are "
            f"tail-padded only — build them with "
            f"level_table()/stack_tables()")
    if not full:
        return table
    width = table.latencies.shape[-1]
    lat = np.asarray(table.latencies).reshape((-1, depth, width))
    ins = np.asarray(table.instr_cycles).reshape((-1, depth))
    bad = pad & (np.any(lat != 0.0, axis=-1) | (ins != 0.0))
    if np.any(bad):
        row, lvl = (int(x) for x in np.argwhere(bad)[0])
        raise ValueError(
            f"level table row {row}, padding level {lvl} (of width "
            f"{width}): identity padding levels must carry zero latency "
            f"and zero instruction overhead")
    return table


# ---------------------------------------------------------------------------
# Degradation-tolerant release semantics: timeout and quorum barriers.
# ---------------------------------------------------------------------------

class FaultSpec(NamedTuple):
    """Release semantics of a degradation-tolerant barrier, as traced
    data (a JAX pytree of scalars/rows — new thresholds never
    recompile anything).

    Every counter of every level releases at

        ``release = min(quorum_done, first_arrival + timeout_cycles)``

    * **quorum**: a counter over ``g`` children releases once
      ``ceil(quorum_frac * g)`` of them have been serviced (K-of-N
      release; ``quorum_frac == 1.0`` is the classical all-arrive
      barrier).
    * **timeout**: a watchdog armed when the counter services its FIRST
      child forces release ``timeout_cycles`` later even if the quorum
      never fills — the hardware-synchronizer bound of Glaser et al.
      (arXiv 2004.06662) against a stalled or dead child deadlocking
      the whole tree.  ``+inf`` disables it.

    Children still missing at release are *abandoned*: the subtree the
    barrier gave up on is charged to ``abandoned_pes`` and its late
    arrival can no longer block any ancestor.  With ``timeout = +inf``
    and ``quorum_frac = 1.0`` the semantics — and, in the simulator,
    the float32 results bit for bit — degenerate to the classical
    barrier.

    ``timeout_cycles`` is a scalar (every level shares the budget) or a
    per-level row aligned with the PADDED level index of the table it
    runs against.  ``e_timeout_poll`` / ``e_abandon`` carry the
    degradation energy surcharges (:func:`repro.core.energy.
    robust_episode_energy`) so the energy column stays pure table+spec
    data.
    """

    timeout_cycles: jnp.ndarray   # () or (L,) float32, +inf = never
    quorum_frac: jnp.ndarray      # () float32 in (0, 1]
    e_timeout_poll: jnp.ndarray   # () float32 pJ / watchdog release
    e_abandon: jnp.ndarray        # () float32 pJ / abandoned PE


def fault_spec(timeout_cycles=jnp.inf, quorum_frac=1.0,
               energy_model: EnergyModel = DEFAULT_ENERGY) -> FaultSpec:
    """Build a :class:`FaultSpec`, validating concrete (untraced)
    thresholds: timeouts must be ``>= 0`` and the quorum fraction in
    ``(0, 1]``."""
    t = jnp.asarray(timeout_cycles, jnp.float32)
    q = jnp.asarray(quorum_frac, jnp.float32)
    if t.ndim > 1:
        raise ValueError(
            f"timeout_cycles must be a scalar or a per-level row, got "
            f"shape {t.shape}")
    if not isinstance(t, jax.core.Tracer) and bool(jnp.any(t < 0)):
        raise ValueError(f"timeout_cycles must be >= 0, got {t}")
    if not isinstance(q, jax.core.Tracer) and not bool(
            jnp.all((q > 0) & (q <= 1))):
        raise ValueError(f"quorum_frac must be in (0, 1], got {q}")
    return FaultSpec(t, q,
                     jnp.float32(energy_model.e_timeout_poll),
                     jnp.float32(energy_model.e_abandon))


# NO_FAULTS (the degenerate spec) is materialized lazily via module
# __getattr__: building it eagerly would create jax arrays at import
# time and lock the backend's device count before entry points like
# repro.launch.dryrun get to set XLA_FLAGS.
def __getattr__(name: str):
    if name == "NO_FAULTS":
        spec = fault_spec()
        globals()["NO_FAULTS"] = spec
        return spec
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def max_depth(n_pes: int) -> int:
    """Depth of the deepest tree over ``n_pes`` cores (radix 2)."""
    return max(1, int(math.log2(n_pes)))


def counter_width(n_pes: int) -> int:
    """Most counters any level of a tree over ``n_pes`` cores can have:
    the leaf level of the radix-2 tree, ``n_pes // 2``."""
    return max(1, n_pes // 2)


def default_widths(n_pes: int, depth: int) -> tuple:
    """The conservative per-step telescope widths ``max(1, N >> i)``:
    valid for ANY canonical table over ``n_pes`` cores (every real
    level at least halves the live count, so the floor-of-halving
    bound holds for non-power-of-two ``N`` too).  Used when the stacked
    group sizes are traced data (e.g. the 5G app core) and the exact
    cumulative quotients cannot be read off on the host."""
    return tuple(max(1, n_pes >> i) for i in range(depth + 1))


def telescope_widths(table: LevelTable, n_pes: int) -> tuple | None:
    """Exact per-step entry widths for the telescoping core: entry
    ``i`` bounds the survivors alive entering step ``i``.

    For one schedule the live count entering level ``i`` is exactly
    ``N // (g_0 * ... * g_{i-1})`` (floored division composes:
    ``(N // a) // b == N // (a * b)``, and the cumulative products of a
    full schedule divide ``N`` exactly) — the *cumulative quotient*.
    For a stacked table the width is the max over stacked rows, so one
    widths tuple serves the whole sweep and the one-compile property
    is untouched.  This is far tighter than the ``N >> i`` bound for
    hierarchy-shaped stacks: a leaf level of 8 shrinks the window 8x
    in one step instead of 2x, cutting the sort volume of the unrolled
    pyramid by ~2x at N=4096 (benchmarks/bench_multicluster.py).

    Returns ``None`` for traced tables — callers then fall back to
    :func:`default_widths` inside the core.
    """
    if isinstance(table.group_sizes, jax.core.Tracer):
        return None
    n = int(n_pes)
    depth = table.group_sizes.shape[-1]
    sizes = np.asarray(table.group_sizes, np.int64).reshape((-1, depth))
    cum = np.cumprod(sizes, axis=1)
    widths = [n]
    for i in range(depth):
        widths.append(int(max(1, np.max(n // cum[:, i]))))
    return tuple(widths)


@functools.lru_cache(maxsize=None)
def _level_table_cached(schedule: BarrierSchedule, max_levels: int,
                        cfg: TeraPoolConfig, placement,
                        energy_model: EnergyModel) -> LevelTable:
    n = schedule.n_pes
    width = counter_width(n)
    sizes = [lvl.group_size for lvl in schedule.levels]
    if schedule.hw:
        if placement is not None:
            raise ValueError(
                "hardware event-unit barriers have no counters to place")
        # The event unit has no software level path and no bank
        # serialization: signals aggregate combinationally per stage.
        instr = [0.0] * len(sizes)
        svc = [0.0] * len(sizes)
        entry = float(cfg.hw_entry_instr)
    else:
        instr = [float(cfg.instr_per_level)] * len(sizes)
        svc = [float(cfg.bank_service_cycles)] * len(sizes)
        entry = float(cfg.instr_per_level)
    pad = max_levels - len(sizes)
    if pad < 0:
        raise ValueError(
            f"schedule has {len(sizes)} levels, max_levels={max_levels}")

    # Identity padding for unused counter columns and padding levels:
    # zero latency, and bank ids that are distinct from every real bank
    # (and from each other) so phantom counters can never contend.
    sentinel = cfg.n_pes * cfg.banking_factor
    lat_rows: list = []
    bank_rows: list = []
    if placement is None:
        # Span-heuristic fallback (paper leaf-local): one latency per
        # level broadcast across its counters, one distinct bank each.
        for lvl in schedule.levels:
            lat_rows.append([float(lvl.latency)] * width)
            bank_rows.append([j * lvl.span * cfg.banking_factor
                              for j in range(width)])
    else:
        if placement.n_levels != len(sizes):
            raise ValueError(
                f"placement maps {placement.n_levels} levels, schedule "
                f"has {len(sizes)}")
        for lvl, lrow, brow in zip(schedule.levels, placement.latencies,
                                   placement.banks):
            count = n // lvl.span
            if len(brow) != count:
                raise ValueError(
                    f"level with span {lvl.span} has {count} counters, "
                    f"placement maps {len(brow)}")
            lat_rows.append(list(map(float, lrow))
                            + [0.0] * (width - count))
            bank_rows.append(list(brow)
                             + [sentinel + j for j in range(count, width)])
    for _ in range(pad):
        lat_rows.append([0.0] * width)
        bank_rows.append(list(range(width)))

    stat, act, idle = schedule_energy_constants(
        schedule, placement, cfg, energy_model)
    return validate_tail_padding(LevelTable(
        group_sizes=jnp.asarray(sizes + [1] * pad, jnp.int32),
        latencies=jnp.asarray(lat_rows, jnp.float32),
        instr_cycles=jnp.asarray(instr + [0.0] * pad, jnp.float32),
        bank_ids=jnp.asarray(bank_rows, jnp.int32),
        service_cycles=jnp.asarray(svc + [0.0] * pad, jnp.float32),
        entry_instr=jnp.float32(entry),
        energy_static=jnp.asarray(stat, jnp.float32),
        active_cycles=jnp.asarray(act, jnp.float32),
        idle_power=jnp.asarray(idle, jnp.float32),
    ))


def level_table(schedule: BarrierSchedule, max_levels: int | None = None,
                cfg: TeraPoolConfig = DEFAULT, *, placement=None,
                energy_model: EnergyModel = DEFAULT_ENERGY) -> LevelTable:
    """Encode ``schedule`` as a padded :class:`LevelTable`.

    ``max_levels`` defaults to ``log2(schedule.n_pes)`` so that *all*
    power-of-two radices over the same cluster share one table shape —
    and hence one compiled simulator.  ``placement`` (a
    :class:`~repro.core.placement.CounterPlacement`) supplies explicit
    per-counter banks and latencies; ``None`` falls back to the legacy
    span heuristic with conflict-free banks.  ``energy_model`` prices
    the schedule's energy scalars (:mod:`repro.core.energy`); being
    table data, swapping models never recompiles a core.
    """
    if max_levels is None:
        max_levels = max_depth(schedule.n_pes)
    return _level_table_cached(schedule, int(max_levels), cfg, placement,
                               energy_model)


def stack_tables(schedules: Sequence[BarrierSchedule],
                 cfg: TeraPoolConfig = DEFAULT,
                 placements: Sequence | None = None,
                 energy_model: EnergyModel = DEFAULT_ENERGY) -> LevelTable:
    """Stack the tables of same-``n_pes`` schedules along a new leading
    axis, ready to ``vmap`` one compiled simulate over the whole radix
    (or radix x placement) sweep.  ``placements`` aligns with
    ``schedules``; ``None`` entries use the span-heuristic fallback."""
    if not schedules:
        raise ValueError("no schedules to stack")
    n = schedules[0].n_pes
    if any(s.n_pes != n for s in schedules):
        raise ValueError("stacked schedules must share n_pes")
    if placements is None:
        placements = [None] * len(schedules)
    if len(placements) != len(schedules):
        raise ValueError(
            f"{len(schedules)} schedules but {len(placements)} placements")
    depth = max(max_depth(n),
                max(s.n_levels for s in schedules))
    tables = [level_table(s, depth, cfg, placement=p,
                          energy_model=energy_model)
              for s, p in zip(schedules, placements)]
    # Each row was fully validated when level_table built it; the
    # stacked check keeps only the cheap group-size suffix test (no
    # host sync of the big stacked latency columns on the hot
    # sweep-setup path).
    return validate_tail_padding(
        jax.tree.map(lambda *xs: jnp.stack(xs), *tables), full=False)
