"""Barrier schedules: central-counter, k-ary tree and partial barriers.

A *schedule* is the static structure of the arrival tree (Sec. 3 of the
paper): how many PEs synchronize per shared counter at every level, and
the locality class (hence latency) of each level's counters.

The radix ``k`` spans the whole design space:
  * ``k == n_pes``  -> linear central-counter barrier (one level),
  * ``k == 2``      -> radix-2 logarithmic tree (log2(N) levels),
  * anything in between is a k-ary tree.  When ``log_k(N)`` is not an
    integer the *first* level uses a smaller group (the paper adapts the
    first step in the same way).

Partial barriers synchronize a contiguous subset of the cluster (e.g. the
256 PEs sharing one FFT) using the per-Group / per-Tile wakeup registers.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import List, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from .topology import DEFAULT, TeraPoolConfig


@dataclasses.dataclass(frozen=True)
class Level:
    """One level of the arrival tree."""

    group_size: int   # PEs (survivors) sharing one counter at this level
    span: int         # contiguous original-PE span covered by one group
    latency: int      # access latency to this level's counters (cycles)


@dataclasses.dataclass(frozen=True)
class BarrierSchedule:
    """Static structure of one barrier instance."""

    n_pes: int                 # PEs synchronized by this barrier
    radix: int
    levels: tuple              # tuple[Level, ...]
    partial: bool = False      # True if a subset-of-cluster barrier

    @property
    def n_levels(self) -> int:
        return len(self.levels)


def _check_pow2(x: int, name: str) -> None:
    if x < 2 or (x & (x - 1)) != 0:
        raise ValueError(f"{name} must be a power of two >= 2, got {x}")


def kary_tree(radix: int, n_pes: int | None = None,
              cfg: TeraPoolConfig = DEFAULT, *,
              partial: bool = False) -> BarrierSchedule:
    """Build the k-ary arrival tree for ``n_pes`` cores.

    ``n_levels = ceil(log_k N)``; the first level synchronizes
    ``N / k**(n_levels-1)`` PEs so the remaining levels are exactly
    radix-k (paper Sec. 3: "adapted ... by synchronizing a number of PEs
    different from the radix of the tree in the first step").
    """
    n = int(n_pes if n_pes is not None else cfg.n_pes)
    k = int(radix)
    _check_pow2(n, "n_pes")
    _check_pow2(k, "radix")
    if k > n:
        raise ValueError(f"radix {k} exceeds n_pes {n}")

    n_levels = math.ceil(math.log(n) / math.log(k))
    first = n // (k ** (n_levels - 1))
    sizes: List[int] = [first] + [k] * (n_levels - 1)
    assert math.prod(sizes) == n

    levels: List[Level] = []
    span = 1
    for g in sizes:
        span *= g
        levels.append(Level(group_size=g, span=span,
                            latency=cfg.access_latency(span)))
    return BarrierSchedule(n_pes=n, radix=k, levels=tuple(levels),
                           partial=partial)


def central_counter(n_pes: int | None = None,
                    cfg: TeraPoolConfig = DEFAULT) -> BarrierSchedule:
    """Linear central-counter barrier: every PE hits one shared counter."""
    n = int(n_pes if n_pes is not None else cfg.n_pes)
    return kary_tree(n, n_pes=n, cfg=cfg)


def partial_barrier(group_pes: int, radix: int,
                    cfg: TeraPoolConfig = DEFAULT) -> BarrierSchedule:
    """Barrier over a contiguous subset of ``group_pes`` cores (uses the
    selective Group/Tile wakeup registers of Fig. 1b)."""
    if group_pes > cfg.n_pes:
        raise ValueError("partial barrier larger than the cluster")
    return kary_tree(radix, n_pes=group_pes, cfg=cfg, partial=True)


def all_radices(n_pes: int | None = None,
                cfg: TeraPoolConfig = DEFAULT) -> Sequence[int]:
    """All power-of-two radices 2..N (N == central counter)."""
    n = int(n_pes if n_pes is not None else cfg.n_pes)
    return [1 << i for i in range(1, int(math.log2(n)) + 1)]


# ---------------------------------------------------------------------------
# Padded level tables: a dense, fixed-shape encoding of any schedule.
# ---------------------------------------------------------------------------

class LevelTable(NamedTuple):
    """Dense ``(max_levels,)`` encoding of a :class:`BarrierSchedule`.

    Every tree over ``n_pes`` cores fits in ``log2(n_pes)`` levels (the
    radix-2 depth), so padding each table to that depth gives every
    schedule of a given cluster size the *same array shapes*: the
    simulator compiles once and sweeps radices as data.  Padding levels
    are the identity — ``group_size == 1`` (each survivor alone at its
    counter), zero latency and zero software overhead — so they pass
    timings through unchanged.

    Being a NamedTuple of arrays, a table is a JAX pytree: it can be
    ``vmap``-ed over a stacked leading axis (see :func:`stack_tables`)
    and fed straight through ``lax.scan``.
    """

    group_sizes: jnp.ndarray    # (L,) int32, 1 past the real depth
    latencies: jnp.ndarray      # (L,) float32, 0 past the real depth
    instr_cycles: jnp.ndarray   # (L,) float32, 0 past the real depth

    @property
    def max_levels(self) -> int:
        return self.group_sizes.shape[-1]


def max_depth(n_pes: int) -> int:
    """Depth of the deepest tree over ``n_pes`` cores (radix 2)."""
    return max(1, int(math.log2(n_pes)))


@functools.lru_cache(maxsize=None)
def _level_table_cached(schedule: BarrierSchedule, max_levels: int,
                        cfg: TeraPoolConfig) -> LevelTable:
    sizes = [lvl.group_size for lvl in schedule.levels]
    lats = [float(lvl.latency) for lvl in schedule.levels]
    instr = [float(cfg.instr_per_level)] * len(sizes)
    pad = max_levels - len(sizes)
    if pad < 0:
        raise ValueError(
            f"schedule has {len(sizes)} levels, max_levels={max_levels}")
    return LevelTable(
        group_sizes=jnp.asarray(sizes + [1] * pad, jnp.int32),
        latencies=jnp.asarray(lats + [0.0] * pad, jnp.float32),
        instr_cycles=jnp.asarray(instr + [0.0] * pad, jnp.float32),
    )


def level_table(schedule: BarrierSchedule, max_levels: int | None = None,
                cfg: TeraPoolConfig = DEFAULT) -> LevelTable:
    """Encode ``schedule`` as a padded :class:`LevelTable`.

    ``max_levels`` defaults to ``log2(schedule.n_pes)`` so that *all*
    power-of-two radices over the same cluster share one table shape —
    and hence one compiled simulator.
    """
    if max_levels is None:
        max_levels = max_depth(schedule.n_pes)
    return _level_table_cached(schedule, int(max_levels), cfg)


def stack_tables(schedules: Sequence[BarrierSchedule],
                 cfg: TeraPoolConfig = DEFAULT) -> LevelTable:
    """Stack the tables of same-``n_pes`` schedules along a new leading
    axis, ready to ``vmap`` one compiled simulate over the whole radix
    sweep."""
    if not schedules:
        raise ValueError("no schedules to stack")
    n = schedules[0].n_pes
    if any(s.n_pes != n for s in schedules):
        raise ValueError("stacked schedules must share n_pes")
    depth = max(max_depth(n),
                max(s.n_levels for s in schedules))
    tables = [level_table(s, depth, cfg) for s in schedules]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *tables)
