"""Barrier schedules: central-counter, k-ary tree and partial barriers.

A *schedule* is the static structure of the arrival tree (Sec. 3 of the
paper): how many PEs synchronize per shared counter at every level, and
the locality class (hence latency) of each level's counters.

The radix ``k`` spans the whole design space:
  * ``k == n_pes``  -> linear central-counter barrier (one level),
  * ``k == 2``      -> radix-2 logarithmic tree (log2(N) levels),
  * anything in between is a k-ary tree.  When ``log_k(N)`` is not an
    integer the *first* level uses a smaller group (the paper adapts the
    first step in the same way).

Partial barriers synchronize a contiguous subset of the cluster (e.g. the
256 PEs sharing one FFT) using the per-Group / per-Tile wakeup registers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence

from .topology import DEFAULT, TeraPoolConfig


@dataclasses.dataclass(frozen=True)
class Level:
    """One level of the arrival tree."""

    group_size: int   # PEs (survivors) sharing one counter at this level
    span: int         # contiguous original-PE span covered by one group
    latency: int      # access latency to this level's counters (cycles)


@dataclasses.dataclass(frozen=True)
class BarrierSchedule:
    """Static structure of one barrier instance."""

    n_pes: int                 # PEs synchronized by this barrier
    radix: int
    levels: tuple              # tuple[Level, ...]
    partial: bool = False      # True if a subset-of-cluster barrier

    @property
    def n_levels(self) -> int:
        return len(self.levels)


def _check_pow2(x: int, name: str) -> None:
    if x < 2 or (x & (x - 1)) != 0:
        raise ValueError(f"{name} must be a power of two >= 2, got {x}")


def kary_tree(radix: int, n_pes: int | None = None,
              cfg: TeraPoolConfig = DEFAULT, *,
              partial: bool = False) -> BarrierSchedule:
    """Build the k-ary arrival tree for ``n_pes`` cores.

    ``n_levels = ceil(log_k N)``; the first level synchronizes
    ``N / k**(n_levels-1)`` PEs so the remaining levels are exactly
    radix-k (paper Sec. 3: "adapted ... by synchronizing a number of PEs
    different from the radix of the tree in the first step").
    """
    n = int(n_pes if n_pes is not None else cfg.n_pes)
    k = int(radix)
    _check_pow2(n, "n_pes")
    _check_pow2(k, "radix")
    if k > n:
        raise ValueError(f"radix {k} exceeds n_pes {n}")

    n_levels = math.ceil(math.log(n) / math.log(k))
    first = n // (k ** (n_levels - 1))
    sizes: List[int] = [first] + [k] * (n_levels - 1)
    assert math.prod(sizes) == n

    levels: List[Level] = []
    span = 1
    for g in sizes:
        span *= g
        levels.append(Level(group_size=g, span=span,
                            latency=cfg.access_latency(span)))
    return BarrierSchedule(n_pes=n, radix=k, levels=tuple(levels),
                           partial=partial)


def central_counter(n_pes: int | None = None,
                    cfg: TeraPoolConfig = DEFAULT) -> BarrierSchedule:
    """Linear central-counter barrier: every PE hits one shared counter."""
    n = int(n_pes if n_pes is not None else cfg.n_pes)
    return kary_tree(n, n_pes=n, cfg=cfg)


def partial_barrier(group_pes: int, radix: int,
                    cfg: TeraPoolConfig = DEFAULT) -> BarrierSchedule:
    """Barrier over a contiguous subset of ``group_pes`` cores (uses the
    selective Group/Tile wakeup registers of Fig. 1b)."""
    if group_pes > cfg.n_pes:
        raise ValueError("partial barrier larger than the cluster")
    return kary_tree(radix, n_pes=group_pes, cfg=cfg, partial=True)


def all_radices(n_pes: int | None = None,
                cfg: TeraPoolConfig = DEFAULT) -> Sequence[int]:
    """All power-of-two radices 2..N (N == central counter)."""
    n = int(n_pes if n_pes is not None else cfg.n_pes)
    return [1 << i for i in range(1, int(math.log2(n)) + 1)]
