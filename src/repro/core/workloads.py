"""Arrival-time models for the paper's benchmark kernels (Sec. 4.2).

Each model produces per-PE *completion times* (cycles) for one parallel
epoch of the kernel — the distribution whose CDF the paper plots in
Fig. 5 and which drives the barrier-radix selection of Fig. 6.  The
models encode the paper's qualitative structure:

* AXPY / DOTP  — strictly local banks, uniform work -> steep CDF;
  DOTP adds an atomic reduction onto ONE shared variable, whose
  single-bank serialization scatters the arrivals by up to N_PE cycles.
* DCT / MATMUL — remote accesses through the shared interconnect;
  contention scatter grows with the input size.  The special layout
  "2x4096" DCT maps every access to a local bank (banking factor 4,
  sequential addresses) -> steepest CDF.
* Conv2D       — locally-constrained accesses but *imbalanced* work:
  PEs computing the zero-padded image border finish early -> bimodal
  CDF with a wide first-to-last gap.

Cycle constants are per-element software costs on a Snitch core
(pseudo-dual-issue, 16/32-bit fixed point) and are deliberately exposed
for calibration.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from .barrier_sim import _serialize_group
from .topology import DEFAULT, TeraPoolConfig


@dataclasses.dataclass(frozen=True)
class KernelCosts:
    axpy_per_elem: float = 3.0     # 2 ld + fmadd + st, local banks
    dotp_per_elem: float = 4.0     # 2 ld + fmadd (+ loop)
    dct_per_elem: float = 14.0     # 8-pt DCT butterflies per sample
    mac: float = 2.5               # MAC incl. avg. remote-load stall
    conv_inner_px: float = 30.0    # 3x3 MACs + ld/st per inner pixel
    conv_border_px: float = 9.0    # zero-skipped border pixel
    startup_jitter: float = 4.0    # scheduling jitter at epoch start
    contention_frac: float = 0.04  # scatter fraction for remote kernels
    local_frac: float = 0.004      # scatter fraction for local kernels


COSTS = KernelCosts()


def _jitter(key: jax.Array, n: int, scale: float) -> jnp.ndarray:
    """Non-negative contention jitter: half-normal + uniform tail."""
    k1, k2 = jax.random.split(key)
    hn = jnp.abs(jax.random.normal(k1, (n,))) * scale
    un = jax.random.uniform(k2, (n,), minval=0.0, maxval=scale)
    return hn + un


def axpy_arrivals(key: jax.Array, n_elems: int,
                  cfg: TeraPoolConfig = DEFAULT,
                  costs: KernelCosts = COSTS) -> jnp.ndarray:
    """y <- a*x + y, strictly tile-local banks."""
    work = (n_elems / cfg.n_pes) * costs.axpy_per_elem
    return work + _jitter(key, cfg.n_pes,
                          costs.startup_jitter + costs.local_frac * work)


def dotp_arrivals(key: jax.Array, n_elems: int,
                  cfg: TeraPoolConfig = DEFAULT,
                  costs: KernelCosts = COSTS) -> jnp.ndarray:
    """Dot product: local MAC loop + atomic add of the partial sum to a
    single shared variable (single-bank serialization -> wide scatter)."""
    work = (n_elems / cfg.n_pes) * costs.dotp_per_elem
    ready = work + _jitter(key, cfg.n_pes,
                           costs.startup_jitter + costs.local_frac * work)
    # All N_PE atomics target one bank; each PE proceeds when its own
    # fetch&add completes.  Sorted completion times are the sorted ready
    # times pushed through the max-plus queue; the arrival *distribution*
    # (what the barrier sees) is exactly that set.
    a = jnp.sort(ready)
    j = jnp.arange(cfg.n_pes, dtype=a.dtype) * cfg.bank_service_cycles
    start = jax.lax.cummax(a - j, axis=0) + j
    return start + cfg.lat_cluster


def dct_arrivals(key: jax.Array, n_elems: int, *, local_layout: bool = False,
                 cfg: TeraPoolConfig = DEFAULT,
                 costs: KernelCosts = COSTS) -> jnp.ndarray:
    """Direct cosine transform; ``local_layout`` models the 2x4096 case
    where sequential addressing makes every access bank-local."""
    work = (n_elems / cfg.n_pes) * costs.dct_per_elem
    if local_layout:
        scale = costs.startup_jitter + costs.local_frac * work
    else:  # contention scatter grows sublinearly (sqrt) with work
        scale = costs.startup_jitter + costs.contention_frac * 25 * work ** 0.5
    return work + _jitter(key, cfg.n_pes, scale)


def matmul_arrivals(key: jax.Array, n: int, p: int, m: int,
                    cfg: TeraPoolConfig = DEFAULT,
                    costs: KernelCosts = COSTS) -> jnp.ndarray:
    """(n x p) @ (p x m): outputs split across PEs, rows/columns fetched
    through the shared interconnect; scatter grows with the input."""
    outs_per_pe = (n * m) / cfg.n_pes
    work = outs_per_pe * p * costs.mac
    scale = costs.startup_jitter + costs.contention_frac * 25 * work ** 0.5
    return work + _jitter(key, cfg.n_pes, scale)


def conv2d_arrivals(key: jax.Array, h: int, w: int,
                    cfg: TeraPoolConfig = DEFAULT,
                    costs: KernelCosts = COSTS) -> jnp.ndarray:
    """3x3 Conv2D: border-assigned PEs resolve zero pixels early."""
    px_per_pe = (h * w) / cfg.n_pes
    border_frac = (2 * h + 2 * w - 4) / (h * w)
    n_border = jnp.maximum(1, jnp.round(border_frac * cfg.n_pes)).astype(int)
    is_border = jnp.arange(cfg.n_pes) < n_border
    work = jnp.where(is_border,
                     px_per_pe * costs.conv_border_px,
                     px_per_pe * costs.conv_inner_px)
    inner_work = px_per_pe * costs.conv_inner_px
    return work + _jitter(key, cfg.n_pes,
                          costs.startup_jitter
                          + costs.local_frac * inner_work)


# ---------------------------------------------------------------------------
# The benchmark suite of Fig. 5 / Fig. 6: kernel x input-dimension grid.
# ---------------------------------------------------------------------------

ArrivalFn = Callable[[jax.Array], jnp.ndarray]


def benchmark_suite(cfg: TeraPoolConfig = DEFAULT,
                    costs: KernelCosts = COSTS
                    ) -> Dict[str, Dict[str, ArrivalFn]]:
    """kernel -> {input-label -> arrival sampler}."""
    def mk(fn, *args, **kw):
        return lambda key: fn(key, *args, cfg=cfg, costs=costs, **kw)

    return {
        "axpy": {
            "256Ki": mk(axpy_arrivals, 1 << 18),
            "512Ki": mk(axpy_arrivals, 1 << 19),
            "1Mi": mk(axpy_arrivals, 1 << 20),
        },
        "dotp": {
            "256Ki": mk(dotp_arrivals, 1 << 18),
            "512Ki": mk(dotp_arrivals, 1 << 19),
            "1Mi": mk(dotp_arrivals, 1 << 20),
        },
        "dct": {
            "2x4096": mk(dct_arrivals, 8192, local_layout=True),
            "64x4096": mk(dct_arrivals, 1 << 18),
            "256x4096": mk(dct_arrivals, 1 << 20),
        },
        "matmul": {
            "128x32x128": mk(matmul_arrivals, 128, 32, 128),
            "256x128x256": mk(matmul_arrivals, 256, 128, 256),
            "512x128x512": mk(matmul_arrivals, 512, 128, 512),
        },
        "conv2d": {
            "128x128": mk(conv2d_arrivals, 128, 128),
            "256x256": mk(conv2d_arrivals, 256, 256),
            "512x512": mk(conv2d_arrivals, 512, 512),
        },
    }


def cdf_first_last_gap(arrivals: jnp.ndarray) -> jnp.ndarray:
    """Fig. 5 summary statistic: slowest-PE minus fastest-PE runtime."""
    return jnp.max(arrivals, axis=-1) - jnp.min(arrivals, axis=-1)
