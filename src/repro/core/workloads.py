"""Arrival-time models for the paper's benchmark kernels (Sec. 4.2).

Each model produces per-PE *completion times* (cycles) for one parallel
epoch of the kernel — the distribution whose CDF the paper plots in
Fig. 5 and which drives the barrier-radix selection of Fig. 6.  The
models encode the paper's qualitative structure:

* AXPY / DOTP  — strictly local banks, uniform work -> steep CDF;
  DOTP adds an atomic reduction onto ONE shared variable, whose
  single-bank serialization scatters the arrivals by up to N_PE cycles.
* DCT / MATMUL — remote accesses through the shared interconnect;
  contention scatter grows with the input size.  The special layout
  "2x4096" DCT maps every access to a local bank (banking factor 4,
  sequential addresses) -> steepest CDF.
* Conv2D       — locally-constrained accesses but *imbalanced* work:
  PEs computing the zero-padded image border finish early -> bimodal
  CDF with a wide first-to-last gap.

Cycle constants are per-element software costs on a Snitch core
(pseudo-dual-issue, 16/32-bit fixed point) and are deliberately exposed
for calibration.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from .barrier_sim import _serialize_group
from .topology import DEFAULT, TeraPoolConfig

# NB: the 5G epoch models below import :mod:`repro.core.fiveg` lazily —
# fiveg never imports this module at top level, so the arrival registry
# can cover its epochs without an import cycle.


@dataclasses.dataclass(frozen=True)
class KernelCosts:
    axpy_per_elem: float = 3.0     # 2 ld + fmadd + st, local banks
    dotp_per_elem: float = 4.0     # 2 ld + fmadd (+ loop)
    dct_per_elem: float = 14.0     # 8-pt DCT butterflies per sample
    mac: float = 2.5               # MAC incl. avg. remote-load stall
    conv_inner_px: float = 30.0    # 3x3 MACs + ld/st per inner pixel
    conv_border_px: float = 9.0    # zero-skipped border pixel
    startup_jitter: float = 4.0    # scheduling jitter at epoch start
    contention_frac: float = 0.04  # scatter fraction for remote kernels
    local_frac: float = 0.004      # scatter fraction for local kernels


COSTS = KernelCosts()


def _jitter(key: jax.Array, n: int, scale: float) -> jnp.ndarray:
    """Non-negative contention jitter: half-normal + uniform tail."""
    k1, k2 = jax.random.split(key)
    hn = jnp.abs(jax.random.normal(k1, (n,))) * scale
    un = jax.random.uniform(k2, (n,), minval=0.0, maxval=scale)
    return hn + un


def axpy_arrivals(key: jax.Array, n_elems: int,
                  cfg: TeraPoolConfig = DEFAULT,
                  costs: KernelCosts = COSTS) -> jnp.ndarray:
    """y <- a*x + y, strictly tile-local banks."""
    work = (n_elems / cfg.n_pes) * costs.axpy_per_elem
    return work + _jitter(key, cfg.n_pes,
                          costs.startup_jitter + costs.local_frac * work)


def dotp_arrivals(key: jax.Array, n_elems: int,
                  cfg: TeraPoolConfig = DEFAULT,
                  costs: KernelCosts = COSTS) -> jnp.ndarray:
    """Dot product: local MAC loop + atomic add of the partial sum to a
    single shared variable (single-bank serialization -> wide scatter)."""
    work = (n_elems / cfg.n_pes) * costs.dotp_per_elem
    ready = work + _jitter(key, cfg.n_pes,
                           costs.startup_jitter + costs.local_frac * work)
    # All N_PE atomics target one bank; each PE proceeds when its own
    # fetch&add completes.  Sorted completion times are the sorted ready
    # times pushed through the max-plus queue; the arrival *distribution*
    # (what the barrier sees) is exactly that set.
    a = jnp.sort(ready)
    j = jnp.arange(cfg.n_pes, dtype=a.dtype) * cfg.bank_service_cycles
    start = jax.lax.cummax(a - j, axis=0) + j
    return start + cfg.lat_cluster


def dct_arrivals(key: jax.Array, n_elems: int, *, local_layout: bool = False,
                 cfg: TeraPoolConfig = DEFAULT,
                 costs: KernelCosts = COSTS) -> jnp.ndarray:
    """Direct cosine transform; ``local_layout`` models the 2x4096 case
    where sequential addressing makes every access bank-local."""
    work = (n_elems / cfg.n_pes) * costs.dct_per_elem
    if local_layout:
        scale = costs.startup_jitter + costs.local_frac * work
    else:  # contention scatter grows sublinearly (sqrt) with work
        scale = costs.startup_jitter + costs.contention_frac * 25 * work ** 0.5
    return work + _jitter(key, cfg.n_pes, scale)


def matmul_arrivals(key: jax.Array, n: int, p: int, m: int,
                    cfg: TeraPoolConfig = DEFAULT,
                    costs: KernelCosts = COSTS) -> jnp.ndarray:
    """(n x p) @ (p x m): outputs split across PEs, rows/columns fetched
    through the shared interconnect; scatter grows with the input."""
    outs_per_pe = (n * m) / cfg.n_pes
    work = outs_per_pe * p * costs.mac
    scale = costs.startup_jitter + costs.contention_frac * 25 * work ** 0.5
    return work + _jitter(key, cfg.n_pes, scale)


def conv2d_arrivals(key: jax.Array, h: int, w: int,
                    cfg: TeraPoolConfig = DEFAULT,
                    costs: KernelCosts = COSTS) -> jnp.ndarray:
    """3x3 Conv2D: border-assigned PEs resolve zero pixels early."""
    px_per_pe = (h * w) / cfg.n_pes
    border_frac = (2 * h + 2 * w - 4) / (h * w)
    n_border = jnp.maximum(1, jnp.round(border_frac * cfg.n_pes)).astype(int)
    is_border = jnp.arange(cfg.n_pes) < n_border
    work = jnp.where(is_border,
                     px_per_pe * costs.conv_border_px,
                     px_per_pe * costs.conv_inner_px)
    inner_work = px_per_pe * costs.conv_inner_px
    return work + _jitter(key, cfg.n_pes,
                          costs.startup_jitter
                          + costs.local_frac * inner_work)


# ---------------------------------------------------------------------------
# The benchmark suite of Fig. 5 / Fig. 6: kernel x input-dimension grid.
# ---------------------------------------------------------------------------

ArrivalFn = Callable[[jax.Array], jnp.ndarray]


def benchmark_suite(cfg: TeraPoolConfig = DEFAULT,
                    costs: KernelCosts = COSTS
                    ) -> Dict[str, Dict[str, ArrivalFn]]:
    """kernel -> {input-label -> arrival sampler}."""
    def mk(fn, *args, **kw):
        return lambda key: fn(key, *args, cfg=cfg, costs=costs, **kw)

    return {
        "axpy": {
            "256Ki": mk(axpy_arrivals, 1 << 18),
            "512Ki": mk(axpy_arrivals, 1 << 19),
            "1Mi": mk(axpy_arrivals, 1 << 20),
        },
        "dotp": {
            "256Ki": mk(dotp_arrivals, 1 << 18),
            "512Ki": mk(dotp_arrivals, 1 << 19),
            "1Mi": mk(dotp_arrivals, 1 << 20),
        },
        "dct": {
            "2x4096": mk(dct_arrivals, 8192, local_layout=True),
            "64x4096": mk(dct_arrivals, 1 << 18),
            "256x4096": mk(dct_arrivals, 1 << 20),
        },
        "matmul": {
            "128x32x128": mk(matmul_arrivals, 128, 32, 128),
            "256x128x256": mk(matmul_arrivals, 256, 128, 256),
            "512x128x512": mk(matmul_arrivals, 512, 128, 512),
        },
        "conv2d": {
            "128x128": mk(conv2d_arrivals, 128, 128),
            "256x256": mk(conv2d_arrivals, 256, 256),
            "512x512": mk(conv2d_arrivals, 512, 512),
        },
    }


def cdf_first_last_gap(arrivals: jnp.ndarray) -> jnp.ndarray:
    """Fig. 5 summary statistic: slowest-PE minus fastest-PE runtime."""
    return jnp.max(arrivals, axis=-1) - jnp.min(arrivals, axis=-1)


# ---------------------------------------------------------------------------
# 5G application epoch models (Fig. 7): the arrival distributions the
# per-epoch workload tuner specializes the app's barriers to.
# ---------------------------------------------------------------------------

def fiveg_stage_arrivals(key: jax.Array, app=None,
                         cfg: TeraPoolConfig = DEFAULT) -> jnp.ndarray:
    """Per-PE arrivals into one FFT butterfly-stage barrier of the 5G
    app (epoch-relative): ``ffts_per_round`` stages of work plus the
    uniform scheduling jitter of :class:`repro.core.fiveg.FiveGConfig`.
    Matches the app simulator's epoch model op-for-op."""
    from .fiveg import FiveGConfig, _epoch_arrivals
    app = app if app is not None else FiveGConfig()
    return _epoch_arrivals(key, jnp.float32(0.0), app.epoch_work,
                           app.epoch_jitter, cfg.n_pes)


def fiveg_matmul_arrivals(key: jax.Array, app=None,
                          cfg: TeraPoolConfig = DEFAULT) -> jnp.ndarray:
    """Per-PE arrivals into the barrier closing the beamforming MATMUL
    row epoch: column-distributed MACs with the app simulator's
    contention scatter (``FiveGConfig.mm_work`` / ``.mm_jitter``, the
    same model the app runs)."""
    from .fiveg import FiveGConfig, _epoch_arrivals
    app = app if app is not None else FiveGConfig()
    n = cfg.n_pes
    return _epoch_arrivals(key, jnp.float32(0.0), app.mm_work(n),
                           app.mm_jitter(n), n)


# ---------------------------------------------------------------------------
# In-machine PE fault models: heavy-tail stragglers, transient stalls,
# permanent fail-stop.  A failed PE "arrives" at +inf — the
# degradation-tolerant simulator cores (timeout/quorum release; see
# repro.core.barrier_sim) count it abandoned instead of hanging.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PEFaultModel:
    """Per-epoch PE degradation model, applied on top of any kernel's
    arrival scatter by :func:`apply_faults`.

    Each PE independently (per epoch) fail-stops with ``p_fail``
    (arrival -> ``+inf``), transiently stalls with ``p_stall``
    (arrival += ``stall_cycles``: an IRQ, a DRAM refresh collision, a
    retried bus transaction), or straggles with ``p_straggler``
    (arrival += a lognormal heavy tail of median ``straggler_scale``
    and shape ``straggler_sigma`` — the classic tail-at-scale model).
    The all-zeros default is a bitwise no-op."""

    p_fail: float = 0.0
    p_stall: float = 0.0
    stall_cycles: float = 2000.0
    p_straggler: float = 0.0
    straggler_scale: float = 500.0
    straggler_sigma: float = 1.0

    def __post_init__(self):
        for name in ("p_fail", "p_stall", "p_straggler"):
            p = getattr(self, name)
            if not 0.0 <= float(p) <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")


NO_PE_FAULTS = PEFaultModel()


def fault_mask(key: jax.Array, n_pes: int, p_fail: float) -> jnp.ndarray:
    """(n_pes,) bool fail-stop mask: True = PE never reaches the
    barrier.  Feed it to ``simulate(..., fault_mask=...)`` (masked
    arrivals become ``+inf`` there) or to :func:`apply_faults`."""
    return jax.random.bernoulli(key, p_fail, (n_pes,))


def apply_faults(key: jax.Array, arrivals: jnp.ndarray,
                 model: PEFaultModel = NO_PE_FAULTS) -> jnp.ndarray:
    """Degrade an arrival vector/batch under ``model``.

    Shape-preserving over any ``(..., n_pes)`` batch; every element
    draws its own fate (per-PE x per-trial independence).  Ordering is
    straggle, then stall, then fail-stop — a PE drawn for several
    fates keeps the worst one (``+inf`` absorbs the additive terms).
    A model with all probabilities zero returns ``arrivals``
    unchanged (bitwise; no RNG is consumed)."""
    arrivals = jnp.asarray(arrivals, jnp.float32)
    if (model.p_fail == 0.0 and model.p_stall == 0.0
            and model.p_straggler == 0.0):
        return arrivals
    k_straggle, k_tail, k_stall, k_fail = jax.random.split(key, 4)
    shape = arrivals.shape
    if model.p_straggler > 0.0:
        tail = model.straggler_scale * jnp.exp(
            model.straggler_sigma * jax.random.normal(k_tail, shape))
        straggles = jax.random.bernoulli(k_straggle, model.p_straggler,
                                         shape)
        arrivals = arrivals + jnp.where(straggles, tail, 0.0)
    if model.p_stall > 0.0:
        stalls = jax.random.bernoulli(k_stall, model.p_stall, shape)
        arrivals = arrivals + jnp.where(stalls,
                                        jnp.float32(model.stall_cycles), 0.0)
    if model.p_fail > 0.0:
        fails = jax.random.bernoulli(k_fail, model.p_fail, shape)
        arrivals = jnp.where(fails, jnp.inf, arrivals)
    return arrivals


def straggler_arrivals(key: jax.Array, n_elems: int, *,
                       tail: str = "lognormal", frac: float = 0.05,
                       cfg: TeraPoolConfig = DEFAULT,
                       costs: KernelCosts = COSTS) -> jnp.ndarray:
    """Heavy-tail straggler epoch: AXPY-like uniform local work where a
    ``frac`` fraction of PEs draws a heavy-tailed extra delay.

    ``tail="lognormal"`` uses the tail-at-scale lognormal (median =
    16 x the startup jitter, sigma 1); ``tail="pareto"`` draws from a
    bounded Pareto (alpha 1.5) spanning [1x, 256x] the base work via
    the inverse CDF — the power-law tail whose p99 dominates its mean.
    Both reuse the machine-calibrated :class:`KernelCosts` constants,
    so the bulk of the CDF matches the fault-free AXPY model."""
    if not 0.0 < frac <= 1.0:
        raise ValueError(f"straggler frac must be in (0, 1], got {frac}")
    k_base, k_pick, k_tail = jax.random.split(key, 3)
    n = cfg.n_pes
    work = (n_elems / n) * costs.axpy_per_elem
    base = work + _jitter(k_base, n,
                          costs.startup_jitter + costs.local_frac * work)
    if tail == "lognormal":
        extra = (16.0 * costs.startup_jitter
                 * jnp.exp(jax.random.normal(k_tail, (n,))))
    elif tail == "pareto":
        alpha, lo, hi = 1.5, work, 256.0 * work
        u = jax.random.uniform(k_tail, (n,))
        extra = (lo ** -alpha
                 - u * (lo ** -alpha - hi ** -alpha)) ** (-1.0 / alpha)
    else:
        raise ValueError(
            f"unknown straggler tail {tail!r}; choose from "
            f"('lognormal', 'pareto')")
    straggles = jax.random.bernoulli(k_pick, frac, (n,))
    return base + jnp.where(straggles, extra, 0.0)


# ---------------------------------------------------------------------------
# Uniform batched sampler API: kernel name -> stacked arrival matrices.
# ---------------------------------------------------------------------------

#: Flat Fig. 5/6 kernel x input names ("dotp_1Mi", "conv2d_512x512", ...).
FIG6_KERNELS: Tuple[str, ...] = tuple(
    f"{kernel}_{label}" for kernel, dims in benchmark_suite().items()
    for label in dims)

#: Every named arrival model: the Fig. 5/6 suite, the 5G epochs, and
#: the heavy-tail straggler epochs of the PE fault models.
ARRIVAL_KERNELS: Tuple[str, ...] = FIG6_KERNELS + (
    "fiveg_fft_stage", "fiveg_matmul_row",
    "straggler_lognormal", "straggler_pareto")


def arrival_fns(cfg: TeraPoolConfig = DEFAULT, costs: KernelCosts = COSTS,
                app=None) -> Dict[str, ArrivalFn]:
    """Flat name -> sampler registry behind :data:`ARRIVAL_KERNELS`.

    ``app`` (a :class:`repro.core.fiveg.FiveGConfig`) parameterizes the
    two 5G epoch models; ``None`` uses the paper's 4x16-FFT design
    point."""
    flat: Dict[str, ArrivalFn] = {}
    for kernel, dims in benchmark_suite(cfg, costs).items():
        for label, fn in dims.items():
            flat[f"{kernel}_{label}"] = fn
    flat["fiveg_fft_stage"] = \
        lambda key: fiveg_stage_arrivals(key, app, cfg)
    flat["fiveg_matmul_row"] = \
        lambda key: fiveg_matmul_arrivals(key, app, cfg)
    flat["straggler_lognormal"] = \
        lambda key: straggler_arrivals(key, 1 << 18, tail="lognormal",
                                       cfg=cfg, costs=costs)
    flat["straggler_pareto"] = \
        lambda key: straggler_arrivals(key, 1 << 18, tail="pareto",
                                       cfg=cfg, costs=costs)
    return flat


def arrival_batch(key: jax.Array, kernel: str, shape: Tuple[int, int],
                  cfg: TeraPoolConfig = DEFAULT, costs: KernelCosts = COSTS,
                  app=None) -> jnp.ndarray:
    """Stacked per-PE arrival matrices for one kernel's epoch model.

    ``shape = (n_trials, n_pes)``: the result row ``t`` is the kernel's
    arrival vector under the ``t``-th split of ``key`` — bit-for-bit
    equal to looping the single-vector sampler over
    ``jax.random.split(key, n_trials)`` (tests/test_workloads.py), but
    drawn in one vmapped call so whole trial batches feed the
    one-compile workload sweeps of :mod:`repro.core.sweep`.

    ``n_pes`` different from ``cfg.n_pes`` re-scales the machine (same
    problem size on a smaller cluster), matching the ``n_pes`` knob of
    the sweep/tuning entry points."""
    n_trials, n_pes = (int(x) for x in shape)
    if n_trials < 1:
        raise ValueError(f"need at least one trial, got {n_trials}")
    if n_pes != cfg.n_pes:
        cfg = dataclasses.replace(cfg, n_pes=n_pes)
    fns = arrival_fns(cfg, costs, app)
    try:
        fn = fns[kernel]
    except KeyError:
        raise ValueError(
            f"unknown arrival kernel {kernel!r}; choose from "
            f"{tuple(fns)}") from None
    return jax.vmap(fn)(jax.random.split(key, n_trials))
