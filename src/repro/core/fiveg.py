"""Model of the paper's full 5G PUSCH application (Sec. 4.3, Fig. 7).

OFDM demodulation = N_RX independent 4096-point radix-4 DIF FFTs, each
scheduled on a 256-PE subset (4 FFTs concurrently across the 1024-PE
cluster); every butterfly stage ends with a barrier.  Digital
beamforming = MATMUL of the (N_B x N_RX) coefficient matrix with the
FFT outputs, column-distributed over all 1024 PEs.

Barrier options (the paper's comparison):
  * ``central``       — global central-counter barrier after every stage;
  * ``tree(k)``       — global k-ary tree barrier after every stage;
  * ``partial(k)``    — k-ary tree over each 256-PE FFT subset only
                        (the selective Group-wakeup registers), global
                        barrier only at the FFT->MATMUL dependency;
  * ``tuned``         — global mixed-radix tree picked by the exhaustive
                        tuner (:mod:`repro.core.tuning`) for this app's
                        arrival scatter (hierarchy-pruned search);
  * ``tuned_partial`` — tuned mixed-radix tree over each FFT subset,
                        tuned global tree at the FFT->MATMUL dependency;
  * ``placed``        — jointly tuned (schedule, counter placement)
                        pair: the tuner also chooses WHICH BANKS hold
                        the counters (:mod:`repro.core.placement`), so
                        bank contention and access locality are tuned
                        together with the tree shape.
  * ``hw``            — the hardware event-unit barrier
                        (:func:`repro.core.barrier.hw_event_unit`,
                        after Glaser et al.'s SCU): single-cycle
                        aggregation stages plus broadcast wakeup —
                        the latency AND energy floor every software
                        tree is measured against.
  * ``workload``      — per-EPOCH workload specialization: the stage
                        barriers are tuned (jointly with placement) on
                        the FFT butterfly-stage arrival model, and the
                        global barrier SEPARATELY on its own epochs
                        (the zero-scatter FFT->MATMUL dependency plus
                        the beamforming-row scatter), via
                        :func:`repro.core.tuning.tune_for_arrivals` —
                        each barrier sees the arrival distribution it
                        will actually face, not a uniform proxy.

Every result exposes the winning stage/global schedule names
(``FiveGResult.stage_schedule`` / ``.global_schedule``,
``@strategy``-suffixed when a tuned counter placement is attached) so
reports can show WHICH tree each mode ended up running.

Scheduling ``ffts_per_round`` independent FFTs between barriers
amortizes synchronization (Fig. 3): more FFTs per round -> lower sync
fraction -> smaller tree-vs-central gap (the paper's 1.6x best case at
fine-grained sync vs. 1.2x / 6.2% overhead on the 4x16-FFT benchmark).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import barrier, barrier_sim
from .barrier import FaultSpec, LevelTable, fault_spec
from .barrier_sim import core_fn
from .energy import DEFAULT_ENERGY, EnergyModel
from .topology import DEFAULT, TeraPoolConfig


@dataclasses.dataclass(frozen=True)
class FiveGConfig:
    n_sc: int = 4096            # sub-carriers (FFT length)
    n_rx: int = 64              # antenna streams (FFTs to run)
    n_beams: int = 32           # output beams
    fft_pes: int = 256          # PEs sharing one FFT
    ffts_per_round: int = 4     # FFTs processed between two barriers
    # Per-PE cycles for one butterfly stage of one 4096-pt FFT on 256 PEs
    # (16 points/PE: complex 32-bit bflys + twiddle loads + bank stores of
    # the stage permutation).  Calibrated so the end-to-end application
    # reproduces the paper's 1.6x tree-vs-central speedup and <=6.2%
    # synchronization fraction (EXPERIMENTS.md §Repro).
    stage_cycles: float = 1000.0
    stage_jitter_frac: float = 0.10
    mac_cycles: float = 2.5     # beamforming MAC incl. row broadcast
    mm_jitter_frac: float = 0.05   # beamforming-epoch contention scatter

    @property
    def n_stages(self) -> int:
        return int(math.log(self.n_sc, 4))  # radix-4 DIF

    @property
    def epoch_work(self) -> float:
        """Per-PE cycles of one barrier-to-barrier epoch."""
        return self.stage_cycles * self.ffts_per_round

    @property
    def epoch_jitter(self) -> float:
        """Arrival scatter entering each stage barrier — the scatter
        the tuned sync modes optimize their schedules for."""
        return self.stage_jitter_frac * self.epoch_work

    @property
    def concurrent_ffts(self) -> int:
        return 1024 // self.fft_pes  # 4 subsets

    @property
    def rounds(self) -> int:
        per_subset = self.n_rx // self.concurrent_ffts
        if per_subset % self.ffts_per_round:
            raise ValueError("ffts_per_round must divide FFTs per subset")
        return per_subset // self.ffts_per_round

    def mm_work(self, n_pes: int) -> float:
        """Per-PE cycles of the beamforming MATMUL epoch: (N_B x N_RX)
        @ (N_RX x N_SC) outputs column-split over ``n_pes`` PEs."""
        return self.n_beams * self.n_sc / n_pes * self.n_rx \
            * self.mac_cycles

    def mm_jitter(self, n_pes: int) -> float:
        """Arrival scatter entering the barrier that closes the
        beamforming epoch (concurrent row reads -> moderate
        contention)."""
        return self.mm_jitter_frac * self.mm_work(n_pes)


class FiveGResult(NamedTuple):
    total_cycles: jnp.ndarray      # end-to-end parallel runtime
    sync_cycles: jnp.ndarray       # mean per-PE cycles inside barriers
    sync_fraction: jnp.ndarray     # sync_cycles / total_cycles
    serial_cycles: jnp.ndarray     # single-Snitch-core runtime
    speedup_serial: jnp.ndarray    # serial / parallel
    sync_energy: jnp.ndarray       # pJ spent inside barriers, all PEs
    total_energy: jnp.ndarray      # sync_energy + compute instruction pJ
    energy_fraction: jnp.ndarray   # sync_energy / total_energy
    # Winning schedule names (static metadata, not arrays): the stage
    # and FFT->MATMUL/global barrier trees this run synchronized with,
    # "@strategy"-suffixed where a tuned counter placement is attached.
    stage_schedule: str = ""
    global_schedule: str = ""
    # Degradation columns (``faults=`` runs; trivial otherwise): the
    # mean fraction of PEs released per barrier episode, and the total
    # watchdog (timeout) releases across the whole pipeline.
    completion_rate: jnp.ndarray | float = 1.0
    timed_out_levels: jnp.ndarray | float = 0.0


@dataclasses.dataclass(frozen=True)
class FiveGFaults:
    """PE-failure mode of the 5G app: a persistent fail-stop mask drawn
    once per run (``fail_rate`` Bernoulli per PE under ``seed``) plus
    the timeout/quorum release policy every barrier then runs with.
    Failed PEs never reach another barrier — surviving PEs release via
    the ``timeout_cycles`` watchdog (or a ``quorum_frac`` < 1 early
    quorum) instead of hanging, and the app's throughput degrades
    instead of deadlocking."""

    fail_rate: float = 0.0
    timeout_cycles: float = 2000.0
    quorum_frac: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if not 0.0 <= float(self.fail_rate) < 1.0:
            raise ValueError(
                f"fail_rate must be in [0, 1), got {self.fail_rate}")


def _epoch_arrivals(key: jax.Array, start: jnp.ndarray, work: float,
                    jitter: float, n: int) -> jnp.ndarray:
    return start + work + jax.random.uniform(key, (n,), minval=0.0,
                                             maxval=jitter)


# Fixed seed for the tuner's Monte-Carlo arrival draws: tuning is part
# of the *schedule construction*, deterministic per design point.
_TUNING_SEED = 1023


@functools.lru_cache(maxsize=None)
def _tuned_schedule(n_pes: int, delay: float, partial_tree: bool,
                    cfg: TeraPoolConfig) -> barrier.BarrierSchedule:
    """Best mixed-radix composition for one arrival scatter.  Cached per
    (n_pes, delay): the tuner sweep runs once per design point, through
    the shared compiled scanned core.  Subset trees (<= 256 PEs) search
    exhaustively — their composition count is small; the full-cluster
    tree uses the hierarchy-aware pruned space (128 vs 512 candidates).

    Like every 5G mode cache below, this reads through the persistent
    on-disk schedule store (:mod:`repro.runtime.schedule_cache`) when
    ``REPRO_SCHEDULE_CACHE`` is set, so a fresh process serves cached
    sync modes without re-running the tuner sweep."""
    from . import tuning
    from ..runtime import schedule_cache
    prune = "none" if n_pes <= 256 else "hierarchy"
    key = ("fiveg_tuned", int(n_pes), float(delay), bool(partial_tree),
           prune, repr(cfg))
    hit = schedule_cache.load(key)
    if hit is not None:
        return schedule_cache.decode_schedule(hit["schedule"], cfg)
    sched = tuning.best_schedule(
        jax.random.PRNGKey(_TUNING_SEED), n_pes, delay=delay, n_trials=8,
        cfg=cfg, prune=prune, partial=partial_tree)
    schedule_cache.store(key,
                         {"schedule": schedule_cache.encode_schedule(sched)})
    return sched


@functools.lru_cache(maxsize=None)
def _placed_schedule(n_pes: int, delay: float, cfg: TeraPoolConfig):
    """Jointly tuned (schedule, placement) pair for one arrival scatter:
    the hierarchy-pruned composition space crossed with every named
    counter-placement strategy, one compiled sweep (cached per design
    point like :func:`_tuned_schedule`, disk store included)."""
    from . import tuning
    from ..runtime import schedule_cache
    prune = "none" if n_pes <= 256 else "hierarchy"
    key = ("fiveg_placed", int(n_pes), float(delay), prune, repr(cfg))
    hit = schedule_cache.load(key)
    if hit is not None:
        return schedule_cache.decode_pair(hit, cfg)
    sched, plc = tuning.best_placed_schedule(
        jax.random.PRNGKey(_TUNING_SEED), n_pes, delay=delay, n_trials=8,
        cfg=cfg, prune=prune)
    schedule_cache.store(key, schedule_cache.encode_pair(sched, plc))
    return sched, plc


def _epoch_arrival_models(app: FiveGConfig, cfg: TeraPoolConfig):
    """The two fixed-seed arrival matrices every workload-conditioned
    5G mode tunes on: the FFT butterfly-stage model for the STAGE
    barrier, and — for the GLOBAL barrier — the FFT->MATMUL data
    dependency (zero scatter: the last stage barrier equalized every
    PE) stacked with the beamforming-row epoch (5% contention scatter)
    along the trial axis."""
    from . import workloads
    n = cfg.n_pes
    k_stage, k_mm = jax.random.split(jax.random.PRNGKey(_TUNING_SEED))
    stage_arr = workloads.arrival_batch(k_stage, "fiveg_fft_stage",
                                        (8, n), cfg=cfg, app=app)
    dep_arr = jnp.zeros((4, n), jnp.float32)
    mm_arr = workloads.arrival_batch(k_mm, "fiveg_matmul_row",
                                     (4, n), cfg=cfg, app=app)
    return stage_arr, jnp.concatenate([dep_arr, mm_arr])


@functools.lru_cache(maxsize=None)
def _workload_schedules(app: FiveGConfig, cfg: TeraPoolConfig):
    """Per-epoch workload-tuned (schedule, placement) pairs for the
    ``sync="workload"`` mode, cached per (app, cfg) — in memory and,
    when enabled, in the persistent schedule store.

    The STAGE barrier is tuned (jointly with counter placement) on the
    FFT butterfly-stage arrival model; the GLOBAL barrier separately on
    the epochs it actually closes (see :func:`_epoch_arrival_models`),
    so its argmin minimizes the summed cost of both episodes rather
    than assuming one uniform proxy scatter."""
    from . import tuning
    from .placement import STRATEGIES
    from ..runtime import schedule_cache
    n = cfg.n_pes
    prune = "none" if n <= 256 else "hierarchy"
    key = ("fiveg_workload", repr(app), prune, repr(cfg))
    hit = schedule_cache.load(key)
    if hit is not None:
        return (schedule_cache.decode_pair(hit["stage"], cfg)
                + schedule_cache.decode_pair(hit["global"], cfg))
    stage_arr, global_arr = _epoch_arrival_models(app, cfg)
    stage_sched, stage_plc, _ = tuning.tune_for_arrivals(
        stage_arr, cfg, prune=prune, placements=STRATEGIES)
    global_sched, global_plc, _ = tuning.tune_for_arrivals(
        global_arr, cfg, prune=prune, placements=STRATEGIES)
    schedule_cache.store(key, {
        "stage": schedule_cache.encode_pair(stage_sched, stage_plc),
        "global": schedule_cache.encode_pair(global_sched, global_plc)})
    return stage_sched, stage_plc, global_sched, global_plc


@functools.lru_cache(maxsize=None)
def _pareto_schedules(app: FiveGConfig, cfg: TeraPoolConfig):
    """Energy-aware twin of :func:`_workload_schedules` for the
    ``sync="pareto"`` mode: same epoch arrival models, same
    composition x placement space, but each barrier picks the KNEE of
    its 2-D latency x energy Pareto front
    (:func:`repro.core.tuning.knee_point`) instead of the pure-cycles
    argmin — faster than the energy-minimal extreme, cheaper than the
    best-by-cycles extreme."""
    from . import tuning
    from .placement import STRATEGIES
    from ..runtime import schedule_cache
    n = cfg.n_pes
    prune = "none" if n <= 256 else "hierarchy"
    key = ("fiveg_pareto", repr(app), prune, repr(cfg))
    hit = schedule_cache.load(key)
    if hit is not None:
        return (schedule_cache.decode_pair(hit["stage"], cfg)
                + schedule_cache.decode_pair(hit["global"], cfg))
    stage_arr, global_arr = _epoch_arrival_models(app, cfg)
    stage_sched, stage_plc, _ = tuning.tune_for_arrivals(
        stage_arr, cfg, prune=prune, placements=STRATEGIES,
        objective="pareto")
    global_sched, global_plc, _ = tuning.tune_for_arrivals(
        global_arr, cfg, prune=prune, placements=STRATEGIES,
        objective="pareto")
    schedule_cache.store(key, {
        "stage": schedule_cache.encode_pair(stage_sched, stage_plc,
                                            objective="pareto"),
        "global": schedule_cache.encode_pair(global_sched, global_plc,
                                             objective="pareto")})
    return stage_sched, stage_plc, global_sched, global_plc


# ---------------------------------------------------------------------------
# Tuning-server client mode: resolve the workload-conditioned sync
# modes through a long-lived repro.runtime.serving.TuningServer instead
# of tuning inline — many app instances (or processes, via the shared
# schedule cache) then amortize ONE batched sweep dispatch.
# ---------------------------------------------------------------------------

_TUNING_SERVER = None


@contextlib.contextmanager
def tuning_server(server):
    """Route ``sync="workload"`` / ``sync="pareto"`` schedule
    resolution through ``server`` (a
    :class:`repro.runtime.serving.TuningServer`) while the context is
    active.  The stage and global barrier requests share one trial
    count and tuning space, so the server fuses them into a single
    batched ``sweep_arrivals`` dispatch — and both answers carry full
    provenance (exact / cache / degraded)."""
    global _TUNING_SERVER
    prev = _TUNING_SERVER
    _TUNING_SERVER = server
    try:
        yield server
    finally:
        _TUNING_SERVER = prev


def _served_schedules(app: FiveGConfig, cfg: TeraPoolConfig,
                      objective: str):
    """Resolve the (stage, global) pairs through the installed server.
    Both requests are submitted before either result is awaited, so
    they coalesce into one dispatch."""
    from .placement import STRATEGIES
    from ..runtime.serving import TuneRequest
    stage_arr, global_arr = _epoch_arrival_models(app, cfg)
    placements = tuple(STRATEGIES)
    t_stage = _TUNING_SERVER.submit(TuneRequest(
        arrivals=stage_arr, cfg=cfg, objective=objective,
        placements=placements))
    t_global = _TUNING_SERVER.submit(TuneRequest(
        arrivals=global_arr, cfg=cfg, objective=objective,
        placements=placements))
    rs, rg = t_stage.result(), t_global.result()
    for resp in (rs, rg):
        if not resp.ok:
            raise RuntimeError(
                f"tuning server failed the request: {resp.detail}")
    return rs.schedule, rs.placement, rg.schedule, rg.placement


def _resolve_schedules(app: FiveGConfig, sync: str, radix: int,
                       cfg: TeraPoolConfig):
    """Stage + global schedules, their counter placements (None =
    span-heuristic fallback) and the partial-group count for a mode."""
    n = cfg.n_pes
    jitter = app.epoch_jitter
    stage_plc = global_plc = global_sched = None
    if sync == "central":
        stage_sched = barrier.central_counter(cfg=cfg)
        partial_groups = 1
    elif sync == "tree":
        stage_sched = barrier.kary_tree(radix, cfg=cfg)
        partial_groups = 1
    elif sync == "partial":
        stage_sched = barrier.partial_barrier(app.fft_pes, radix, cfg=cfg)
        partial_groups = n // app.fft_pes
    elif sync == "tuned":
        stage_sched = _tuned_schedule(n, jitter, False, cfg)
        partial_groups = 1
    elif sync == "tuned_partial":
        stage_sched = _tuned_schedule(app.fft_pes, jitter, True, cfg)
        partial_groups = n // app.fft_pes
    elif sync == "placed":
        stage_sched, stage_plc = _placed_schedule(n, jitter, cfg)
        partial_groups = 1
    elif sync == "hw":
        stage_sched = barrier.hw_event_unit(cfg=cfg)
        global_sched = stage_sched
        partial_groups = 1
    elif sync == "workload":
        if _TUNING_SERVER is not None:
            (stage_sched, stage_plc, global_sched,
             global_plc) = _served_schedules(app, cfg, "cycles")
        else:
            (stage_sched, stage_plc,
             global_sched, global_plc) = _workload_schedules(app, cfg)
        partial_groups = 1
    elif sync == "pareto":
        if _TUNING_SERVER is not None:
            (stage_sched, stage_plc, global_sched,
             global_plc) = _served_schedules(app, cfg, "pareto")
        else:
            (stage_sched, stage_plc,
             global_sched, global_plc) = _pareto_schedules(app, cfg)
        partial_groups = 1
    else:
        raise ValueError(f"unknown sync mode {sync!r}")
    if sync in ("tuned", "tuned_partial"):
        global_sched = _tuned_schedule(n, jitter, False, cfg)
    elif sync == "placed":
        global_sched, global_plc = stage_sched, stage_plc
    elif global_sched is None:   # modes without their own global tree
        global_sched = barrier.kary_tree(min(radix, 32), cfg=cfg)
    return stage_sched, global_sched, partial_groups, stage_plc, global_plc


@partial(jax.jit,
         static_argnames=("n_epochs", "partial_groups", "n_pes", "cfg",
                          "core"))
def _app_core(key: jax.Array, stage_table: LevelTable,
              global_table: LevelTable, epoch_work: jnp.ndarray,
              jitter: jnp.ndarray, mm_work: jnp.ndarray,
              mm_jitter: jnp.ndarray, *, n_epochs: int,
              partial_groups: int, n_pes: int,
              cfg: TeraPoolConfig, core: str):
    """Scanned epoch pipeline: one compile per sync mode.

    The epoch loop is a ``lax.scan`` over pre-split keys; the barrier
    radix lives in the (traced) level-table values, so sweeping it
    reuses the compiled program.  ``partial_groups`` shapes the reshape
    and — with the simulator ``core`` selector — is the only
    mode-dependent static.
    """
    sim = core_fn(core)
    keys = jax.random.split(key, n_epochs + 2)
    fft_pes = n_pes // partial_groups

    def epoch(carry, k):
        t, acc, acc_e = carry
        arr = _epoch_arrivals(k, t, epoch_work, jitter, n_pes)
        if partial_groups > 1:
            grp = arr.reshape(partial_groups, fft_pes)
            res = jax.vmap(lambda a: sim(a, stage_table, cfg))(grp)
            t = jnp.repeat(res.exit_time, fft_pes)
            acc = acc + jnp.mean(res.mean_residency)
            acc_e = acc_e + jnp.sum(res.energy)
        else:
            res = sim(arr, stage_table, cfg)
            t = jnp.full((n_pes,), res.exit_time)
            acc = acc + res.mean_residency
            acc_e = acc_e + res.energy
        return (t, acc, acc_e), None

    t = jnp.zeros((n_pes,), jnp.float32)   # per-PE current time
    sync_acc = jnp.asarray(0.0)            # accumulated mean barrier cycles
    energy_acc = jnp.asarray(0.0)          # accumulated barrier energy (pJ)
    (t, sync_acc, energy_acc), _ = jax.lax.scan(
        epoch, (t, sync_acc, energy_acc), keys[:n_epochs])

    # FFT -> beamforming data dependency: one global barrier.
    res = sim(t, global_table, cfg)
    t = jnp.full((n_pes,), res.exit_time)
    sync_acc = sync_acc + res.mean_residency
    energy_acc = energy_acc + res.energy

    # Beamforming MATMUL: (N_B x N_RX) @ (N_RX x N_SC), column-wise over
    # all PEs; concurrent row reads -> moderate contention scatter.
    arr = _epoch_arrivals(keys[n_epochs], t, mm_work, mm_jitter, n_pes)
    res = sim(arr, global_table, cfg)
    return (res.exit_time, sync_acc + res.mean_residency,
            energy_acc + res.energy)


@partial(jax.jit,
         static_argnames=("n_epochs", "partial_groups", "n_pes", "cfg",
                          "core"))
def _app_core_robust(key: jax.Array, stage_table: LevelTable,
                     global_table: LevelTable, epoch_work: jnp.ndarray,
                     jitter: jnp.ndarray, mm_work: jnp.ndarray,
                     mm_jitter: jnp.ndarray, mask: jnp.ndarray,
                     spec: FaultSpec, *, n_epochs: int,
                     partial_groups: int, n_pes: int,
                     cfg: TeraPoolConfig, core: str):
    """Degradation-tolerant twin of :func:`_app_core`: every barrier
    runs the timeout/quorum robust core, and the persistent fail-stop
    ``mask`` turns its PEs' arrivals into ``+inf`` at EVERY barrier
    entry (failed PEs stay failed across epochs).  Returns the extra
    (abandoned-PE, timed-out-level) totals alongside the plain
    accumulators; mask and spec are traced data, so sweeping the
    failure rate or the release policy reuses one compiled program."""
    sim = core_fn(core, robust=True)
    keys = jax.random.split(key, n_epochs + 2)
    fft_pes = n_pes // partial_groups

    def epoch(carry, k):
        t, acc, acc_e, acc_ab, acc_t = carry
        arr = _epoch_arrivals(k, t, epoch_work, jitter, n_pes)
        arr = jnp.where(mask, jnp.inf, arr)
        if partial_groups > 1:
            grp = arr.reshape(partial_groups, fft_pes)
            res = jax.vmap(
                lambda a: sim(a, stage_table, cfg, None, spec))(grp)
            t = jnp.repeat(res.exit_time, fft_pes)
            acc = acc + jnp.mean(res.mean_residency)
            acc_e = acc_e + jnp.sum(res.energy)
            acc_ab = acc_ab + jnp.sum(res.abandoned_pes)
            acc_t = acc_t + jnp.sum(res.timed_out_levels)
        else:
            res = sim(arr, stage_table, cfg, None, spec)
            t = jnp.full((n_pes,), res.exit_time)
            acc = acc + res.mean_residency
            acc_e = acc_e + res.energy
            acc_ab = acc_ab + res.abandoned_pes
            acc_t = acc_t + res.timed_out_levels
        return (t, acc, acc_e, acc_ab, acc_t), None

    t = jnp.zeros((n_pes,), jnp.float32)   # per-PE current time
    sync_acc = jnp.asarray(0.0)            # accumulated mean barrier cycles
    energy_acc = jnp.asarray(0.0)          # accumulated barrier energy (pJ)
    ab_acc = jnp.asarray(0, jnp.int32)     # abandoned PEs, all episodes
    t_acc = jnp.asarray(0, jnp.int32)      # watchdog releases
    (t, sync_acc, energy_acc, ab_acc, t_acc), _ = jax.lax.scan(
        epoch, (t, sync_acc, energy_acc, ab_acc, t_acc), keys[:n_epochs])

    # FFT -> beamforming data dependency: one global barrier (failed
    # PEs never reach it either).
    res = sim(jnp.where(mask, jnp.inf, t), global_table, cfg, None, spec)
    t = jnp.full((n_pes,), res.exit_time)
    sync_acc = sync_acc + res.mean_residency
    energy_acc = energy_acc + res.energy
    ab_acc = ab_acc + res.abandoned_pes
    t_acc = t_acc + res.timed_out_levels

    # Beamforming MATMUL barrier (see _app_core).
    arr = _epoch_arrivals(keys[n_epochs], t, mm_work, mm_jitter, n_pes)
    arr = jnp.where(mask, jnp.inf, arr)
    res = sim(arr, global_table, cfg, None, spec)
    n_episodes = jnp.float32(n_epochs + 2)
    completion = 1.0 - ((ab_acc + res.abandoned_pes).astype(jnp.float32)
                        / (n_episodes * jnp.float32(n_pes)))
    return (res.exit_time, sync_acc + res.mean_residency,
            energy_acc + res.energy, completion,
            (t_acc + res.timed_out_levels).astype(jnp.float32))


def _compute_energy(app: FiveGConfig, n: int, n_epochs: int,
                    model: EnergyModel) -> jnp.ndarray:
    """Instruction energy of the application's COMPUTE cycles (pJ): the
    per-PE epoch work plus the beamforming MATMUL, across all PEs —
    the arrival-independent denominator of ``energy_fraction``."""
    per_pe = n_epochs * app.epoch_work + app.mm_work(n)
    return jnp.float32(model.e_instr * n * per_pe)


def simulate_app(key: jax.Array, app: FiveGConfig = FiveGConfig(),
                 sync: str = "partial", radix: int = 32,
                 cfg: TeraPoolConfig = DEFAULT, *,
                 core: str | None = None,
                 energy_model: EnergyModel = DEFAULT_ENERGY,
                 faults: FiveGFaults | None = None) -> FiveGResult:
    """Simulate the full OFDM + beamforming pipeline under one barrier
    strategy.  ``sync`` in {"central", "tree", "partial", "tuned",
    "tuned_partial", "placed", "workload", "pareto", "hw"}; ``radix``
    is ignored by the tuned, placed, workload, pareto and hw modes (the
    schedule — and for ``placed``/``workload``/``pareto`` the
    counter->bank mapping too — comes from the mixed-radix tuner;
    ``workload`` additionally tunes the stage and global barriers
    SEPARATELY on their own epoch arrival models; ``pareto`` is the
    energy-aware twin that picks the knee of each barrier's 2-D
    latency x energy Pareto front; ``hw`` runs every barrier on the
    hardware event unit).  Inside a :func:`tuning_server` context the
    workload/pareto schedules resolve through the serving daemon.
    ``core`` selects the simulator implementation for every barrier of
    every mode (telescope default; see :mod:`repro.core.barrier_sim`);
    ``energy_model`` prices the energy columns
    (:mod:`repro.core.energy`).

    ``faults`` (a :class:`FiveGFaults`) runs the whole pipeline under
    persistent PE fail-stops with timeout/quorum barrier release: the
    result's ``completion_rate`` / ``timed_out_levels`` columns report
    the degradation, and ``total_cycles`` stays finite as long as the
    release policy is non-trivial.  ``faults=None`` runs the fault-free
    plain cores, bit-for-bit the legacy result.

    The ~25-epoch pipeline runs as one jitted ``lax.scan``; changing the
    radix — or swapping in any tuned schedule or placement of the same
    cluster — does not retrace, because schedule and placement live in
    traced level-table values.
    """
    n = cfg.n_pes
    (stage_sched, global_sched, partial_groups, stage_plc,
     global_plc) = _resolve_schedules(app, sync, radix, cfg)
    stage_table = barrier.level_table(stage_sched, cfg=cfg,
                                      placement=stage_plc,
                                      energy_model=energy_model)
    global_table = barrier.level_table(global_sched, cfg=cfg,
                                       placement=global_plc,
                                       energy_model=energy_model)

    epoch_work = app.epoch_work
    jitter = app.epoch_jitter
    n_epochs = app.rounds * app.n_stages

    completion = 1.0
    timed = 0.0
    if faults is None:
        total, sync_acc, energy_acc = _app_core(
            key, stage_table, global_table, jnp.float32(epoch_work),
            jnp.float32(jitter), jnp.float32(app.mm_work(n)),
            jnp.float32(app.mm_jitter(n)), n_epochs=n_epochs,
            partial_groups=partial_groups, n_pes=n, cfg=cfg,
            core=barrier_sim.resolve_core(core))
    else:
        mask = jax.random.bernoulli(jax.random.PRNGKey(faults.seed),
                                    faults.fail_rate, (n,))
        spec = fault_spec(timeout_cycles=faults.timeout_cycles,
                          quorum_frac=faults.quorum_frac,
                          energy_model=energy_model)
        total, sync_acc, energy_acc, completion, timed = _app_core_robust(
            key, stage_table, global_table, jnp.float32(epoch_work),
            jnp.float32(jitter), jnp.float32(app.mm_work(n)),
            jnp.float32(app.mm_jitter(n)), mask, spec, n_epochs=n_epochs,
            partial_groups=partial_groups, n_pes=n, cfg=cfg,
            core=barrier_sim.resolve_core(core))

    # Serial single-core reference (no barriers, same per-PE work model).
    fft_work = app.n_rx * app.n_stages * app.fft_pes * app.stage_cycles
    mm_serial = app.n_beams * app.n_sc * app.n_rx * app.mac_cycles
    serial = jnp.asarray(fft_work + mm_serial, jnp.float32)
    total_energy = _compute_energy(app, n, n_epochs, energy_model) \
        + energy_acc

    return FiveGResult(
        total_cycles=total,
        sync_cycles=sync_acc,
        sync_fraction=sync_acc / total,
        serial_cycles=serial,
        speedup_serial=serial / total,
        sync_energy=energy_acc,
        total_energy=total_energy,
        energy_fraction=energy_acc / total_energy,
        stage_schedule=barrier.schedule_name(stage_sched, stage_plc),
        global_schedule=barrier.schedule_name(global_sched, global_plc),
        completion_rate=completion,
        timed_out_levels=timed,
    )


def degradation_curve(key: jax.Array,
                      fail_rates=(0.0, 0.005, 0.01, 0.02, 0.05),
                      app: FiveGConfig = FiveGConfig(),
                      modes: tuple = ("central", "tree", "hw"),
                      radix: int = 32,
                      cfg: TeraPoolConfig = DEFAULT, *,
                      core: str | None = None,
                      timeout_cycles: float = 2000.0,
                      quorum_frac: float = 1.0,
                      energy_model: EnergyModel = DEFAULT_ENERGY) -> dict:
    """5G throughput vs. PE-failure rate, per sync mode: one
    :class:`FiveGResult` per (mode, fail_rate), all rates of one mode
    through the SAME compiled robust pipeline (the mask and release
    spec are traced data).  Returns ``{"fail_rates": tuple, mode:
    [FiveGResult, ...]}`` with the per-mode list aligned to
    ``fail_rates`` — the Fig. 7 comparison bent into a degradation
    curve: how gracefully each barrier strategy sheds throughput as
    PEs die, under a ``timeout_cycles`` watchdog (and optionally a
    ``quorum_frac`` < 1 early-release quorum)."""
    rates = tuple(float(r) for r in fail_rates)
    out: dict = {"fail_rates": rates}
    for mode in modes:
        out[mode] = [
            simulate_app(key, app, sync=mode, radix=radix, cfg=cfg,
                         core=core, energy_model=energy_model,
                         faults=FiveGFaults(fail_rate=r,
                                            timeout_cycles=timeout_cycles,
                                            quorum_frac=quorum_frac,
                                            seed=i))
            for i, r in enumerate(rates)]
    return out


def simulate_app_reference(key: jax.Array, app: FiveGConfig = FiveGConfig(),
                           sync: str = "partial", radix: int = 32,
                           cfg: TeraPoolConfig = DEFAULT) -> FiveGResult:
    """The seed unrolled epoch loop over the per-level reference
    simulator — the equivalence oracle for :func:`simulate_app`.  The
    ``placed`` mode routes through the placement-aware per-bank-queue
    oracle instead.  Retraces every epoch; use only in tests."""
    from . import placement as placement_mod
    n = cfg.n_pes
    (stage_sched, global_sched, partial_groups, stage_plc,
     global_plc) = _resolve_schedules(app, sync, radix, cfg)

    def ref(arr, sched, plc):
        if plc is None:
            return barrier_sim.simulate_reference(arr, sched, cfg)
        return placement_mod.simulate_placed_reference(arr, sched, plc, cfg)

    epoch_work = app.epoch_work
    jitter = app.epoch_jitter
    n_epochs = app.rounds * app.n_stages

    t = jnp.zeros((n,), jnp.float32)       # per-PE current time
    sync_acc = jnp.asarray(0.0)            # accumulated mean barrier cycles
    energy_acc = jnp.asarray(0.0)          # accumulated barrier energy (pJ)

    keys = jax.random.split(key, n_epochs + 2)
    for e in range(n_epochs):
        arr = _epoch_arrivals(keys[e], t, epoch_work, jitter, n)
        if partial_groups > 1:
            grp = arr.reshape(partial_groups, app.fft_pes)
            res = ref(grp, stage_sched, stage_plc)
            t = jnp.repeat(res.exit_time, app.fft_pes)
            sync_acc = sync_acc + jnp.mean(res.mean_residency)
            energy_acc = energy_acc + jnp.sum(res.energy)
        else:
            res = ref(arr, stage_sched, stage_plc)
            t = jnp.full((n,), res.exit_time)
            sync_acc = sync_acc + res.mean_residency
            energy_acc = energy_acc + res.energy

    # FFT -> beamforming data dependency: one global barrier.
    res = ref(t, global_sched, global_plc)
    t = jnp.full((n,), res.exit_time)
    sync_acc = sync_acc + res.mean_residency
    energy_acc = energy_acc + res.energy

    # Beamforming MATMUL (see _app_core).
    arr = _epoch_arrivals(keys[-2], t, jnp.float32(app.mm_work(n)),
                          jnp.float32(app.mm_jitter(n)), n)
    res = ref(arr, global_sched, global_plc)
    total = res.exit_time
    sync_acc = sync_acc + res.mean_residency
    energy_acc = energy_acc + res.energy

    # Serial single-core reference (no barriers, same per-PE work model).
    fft_work = app.n_rx * app.n_stages * app.fft_pes * app.stage_cycles
    mm_serial = app.n_beams * app.n_sc * app.n_rx * app.mac_cycles
    serial = jnp.asarray(fft_work + mm_serial, jnp.float32)
    total_energy = _compute_energy(app, n, n_epochs, DEFAULT_ENERGY) \
        + energy_acc

    return FiveGResult(
        total_cycles=total,
        sync_cycles=sync_acc,
        sync_fraction=sync_acc / total,
        serial_cycles=serial,
        speedup_serial=serial / total,
        sync_energy=energy_acc,
        total_energy=total_energy,
        energy_fraction=energy_acc / total_energy,
        stage_schedule=barrier.schedule_name(stage_sched, stage_plc),
        global_schedule=barrier.schedule_name(global_sched, global_plc),
    )


def compare_barriers(key: jax.Array, app: FiveGConfig = FiveGConfig(),
                     radix: int = 32,
                     cfg: TeraPoolConfig = DEFAULT,
                     modes: tuple = ("central", "tree", "partial"), *,
                     core: str | None = None) -> dict:
    """Fig. 7 comparison; returns per-strategy results + per-mode
    speedups AND sync-energy ratios over the central-counter baseline.
    Pass ``modes`` including ``"tuned"`` / ``"tuned_partial"`` /
    ``"placed"`` / ``"workload"`` to compare the mixed-radix tuner's
    schedules (the jointly tuned counter placement, and the per-epoch
    workload specialization) against the fixed-radix strategies, and
    ``"hw"`` for the hardware event-unit floor on both axes."""
    if "central" not in modes:
        raise ValueError("modes must include the 'central' baseline")
    out = {}
    for mode in modes:
        out[mode] = simulate_app(key, app, sync=mode, radix=radix, cfg=cfg,
                                 core=core)
    base = out["central"].total_cycles
    base_energy = out["central"].sync_energy
    for mode in modes:
        if mode != "central":
            out[f"speedup_{mode}"] = base / out[mode].total_cycles
            out[f"energy_ratio_{mode}"] = base_energy / out[mode].sync_energy
    return out
