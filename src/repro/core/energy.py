"""Per-barrier energy accounting: the second objective axis.

The paper tunes barriers for cycles only; its own lineage argues the
real objective is joint latency x energy.  Glaser et al.
("Energy-Efficient Hardware-Accelerated Synchronization for
Shared-L1-Memory Multiprocessor Clusters", arXiv 2004.06662) show a
dedicated synchronization/event unit with WFI sleep beats software
barriers on BOTH axes; MemPool (arXiv 2303.17742) is the shared-L1
substrate TeraPool scales up.  This module prices one barrier episode
in picojoules under an explicit per-event cost model so the sweep and
tuner can trade cycles against energy (:func:`repro.core.tuning.
pareto_front`).

An episode's energy decomposes into a *static* part — fixed by the
schedule, placement, machine config and cost model, independent of the
arrival draw — and a *dynamic* idle-wait part proportional to the time
PEs spend inside the barrier:

* **instruction energy** — every active software cycle (barrier entry,
  per-level compare/branch/reset bookkeeping of the survivors) costs
  ``e_instr``; the hardware event unit replaces all of it with one
  trigger-register store (``cfg.hw_entry_instr`` cycles per PE).
* **atomic RMW traffic** — each fetch&add costs ``e_amo_issue`` at the
  bank plus ``e_amo_hop`` per cycle of interconnect distance, so the
  locality class of every counter (Tile / Group / cluster /
  ``lat_remote``) prices its accesses: one remote-cluster atomic costs
  ~5x a Group-local one in nJ just as it does in cycles.  Hardware
  arrival signals are dedicated wires (``e_hw_signal`` +
  ``e_hw_hop`` x stage latency), not L1 accesses.
* **wakeup fan-out** — one wakeup-register write, one hardwired line
  toggle per PE, and a WFI resume per sleeping core.
* **idle wait** — every PE-cycle inside the barrier not spent executing
  instructions is spent waiting (WFI-slept, or stalled on a pending
  atomic response — Snitch's scoreboard clock-gates the core either
  way) and leaks ``p_wfi`` per cycle; a polling barrier instead burns
  ``p_poll`` on its spin loop (``sleep="poll"``).

The split is what keeps the JAX cores bit-for-bit reproducible: the
static part and the episode's *active* instruction-cycle count are
host-side scalars baked into the
:class:`~repro.core.barrier.LevelTable` (so different cost models are
still ONE compiled program — the constants are traced data), and the
dynamic part is derived inside the core from ``mean_residency``, a
quantity every implementation already computes identically:

    energy = energy_static
             + idle_power * (n * mean_residency - active_cycles)

:func:`energy_reference` recomputes all of it independently — explicit
per-event counting loops plus a numpy per-bank-queue episode walk —
and is the oracle the JAX energy columns are validated against
bit-for-bit (tests/test_energy.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .topology import DEFAULT, TeraPoolConfig


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    """Per-event energy costs (pJ) and idle power (pJ/cycle).

    The defaults are scaled to 22FDX-class numbers in the spirit of
    Glaser et al. (arXiv 2004.06662) — an integer core cycle ~1 pJ, an
    L1 atomic a full round trip incl. the read-modify-write at the bank
    (~15x a core cycle) plus distance, deep clock-gated WFI leaking
    ~0.2% of active power — chosen for realistic *ratios*, not absolute
    calibration; re-fit the fields for a different node.  The
    issue-vs-idle balance is what opens the latency x energy trade:
    every extra tree level costs a round of counter RMWs, every extra
    span cycle costs idle leakage, so deep hierarchy-matched trees win
    cycles while wide shallow trees win energy
    (:func:`repro.core.tuning.pareto_front`).  Frozen + hashable so a
    model can key the level-table cache like the config does.
    """

    e_instr: float = 1.0        # pJ / active instruction cycle
    e_amo_issue: float = 15.0   # pJ / atomic round trip incl. bank RMW
    e_amo_hop: float = 1.5      # pJ / cycle of interconnect distance
    e_hw_signal: float = 0.4    # pJ / event-unit arrival signal
    e_hw_hop: float = 0.2      # pJ / cycle of signal distance
    e_wakeup_write: float = 12.0   # pJ, wakeup-register write (AXI)
    e_wakeup_line: float = 0.6     # pJ / PE wakeup-line toggle
    e_wfi_wake: float = 5.0        # pJ / WFI resume of one core
    p_wfi: float = 0.002       # pJ / cycle, clock-gated in WFI / stalled
    p_poll: float = 0.6        # pJ / cycle, spin-polling the counter
    sleep: str = "wfi"         # "wfi" | "poll"
    # Degradation-tolerant barriers (timeout/quorum release): a level
    # that releases by watchdog pays one deadline-check + abandon-mark
    # round at its counters; every PE the tree gives up on pays the
    # cleanup cost of invalidating its pending arrival.
    e_timeout_poll: float = 8.0   # pJ / level released by watchdog
    e_abandon: float = 25.0       # pJ / abandoned PE (cleanup traffic)

    @property
    def idle_power(self) -> float:
        """pJ per idle PE-cycle under the selected wait policy."""
        if self.sleep not in ("wfi", "poll"):
            raise ValueError(
                f"unknown sleep policy {self.sleep!r}; 'wfi' or 'poll'")
        return self.p_wfi if self.sleep == "wfi" else self.p_poll


DEFAULT_ENERGY = EnergyModel()


def _level_counts(schedule):
    """Per level: (level, survivors entering, counters)."""
    m = schedule.n_pes
    out = []
    for lvl in schedule.levels:
        count = m // lvl.group_size
        out.append((lvl, m, count))
        m = count
    return out


def schedule_energy_constants(schedule, placement=None,
                              cfg: TeraPoolConfig = DEFAULT,
                              model: EnergyModel = DEFAULT_ENERGY
                              ) -> tuple:
    """The three per-episode scalars the simulator cores carry in the
    level table: ``(energy_static, active_cycles, idle_power)``.

    * ``active_cycles`` — total instruction cycles across all PEs:
      ``n`` barrier entries plus each level's survivors' bookkeeping
      (software trees), or ``n`` trigger-register stores (hardware).
    * ``energy_static`` — instruction energy + atomic/signal traffic
      (per counter, at its placement-derived access latency) + the
      wakeup fan-out.  Fixed by the schedule; arrival-independent.
    * ``idle_power`` — pJ per idle PE-cycle; multiplies
      ``n * mean_residency - active_cycles`` inside the core.

    Computed in float64 and rounded ONCE to float32, so every
    implementation (scan, telescope, references, sweeps) that consumes
    these exact scalars produces bit-for-bit identical energy columns.
    """
    n = schedule.n_pes
    hw = bool(getattr(schedule, "hw", False))
    if hw and placement is not None:
        raise ValueError(
            "hardware event-unit barriers have no counters to place")

    if hw:
        active = float(n * cfg.hw_entry_instr)
        traffic = sum(
            m * (model.e_hw_signal + model.e_hw_hop * lvl.latency)
            for lvl, m, _ in _level_counts(schedule))
    else:
        active = float(n * cfg.instr_per_level)
        traffic = 0.0
        for li, (lvl, m, count) in enumerate(_level_counts(schedule)):
            lats = (np.asarray(placement.latencies[li], np.float64)
                    if placement is not None
                    else np.full(count, float(lvl.latency)))
            traffic += lvl.group_size * (
                model.e_amo_issue * count + model.e_amo_hop * lats.sum())
            active += count * cfg.instr_per_level

    wakeup = model.e_wakeup_write + n * model.e_wakeup_line
    if model.sleep == "wfi":
        wakeup += (n - 1) * model.e_wfi_wake

    static = model.e_instr * active + traffic + wakeup
    return (np.float32(static), np.float32(active),
            np.float32(model.idle_power))


@partial(jax.jit, static_argnums=(3,))
def episode_energy(energy_static, active_cycles, idle_power, n_pes,
                   mean_residency):
    """The shared energy formula, in the one op order every
    implementation uses: static events + idle leakage over the
    PE-cycles spent waiting (total residency minus active cycles).

    Jitted on purpose: XLA contracts the multiply-adds into FMAs, so an
    eager caller (the reference oracles) would land one ulp off the
    jitted cores.  Routing every implementation through this one
    compiled formula keeps the energy column bit-for-bit identical
    everywhere (inside an outer jit the call inlines into the same
    contraction)."""
    return energy_static + idle_power * (
        n_pes * mean_residency - active_cycles)


@partial(jax.jit, static_argnums=(3,))
def robust_episode_energy(energy_static, active_cycles, idle_power, n_pes,
                          mean_residency, e_timeout_poll, timed_out_levels,
                          e_abandon, abandoned_pes):
    """:func:`episode_energy` plus the degradation surcharges: one
    watchdog-release round per timed-out level, one cleanup round per
    abandoned PE.  Jitted for the same reason as the base formula — one
    compiled op order shared by cores and oracles — and built ON TOP of
    it so a zero-fault episode (``timed_out_levels == 0``,
    ``abandoned_pes == 0``) reproduces the plain energy column bit for
    bit (``x + c*0 == x`` in IEEE-754 for the finite, positive energies
    involved)."""
    base = episode_energy(energy_static, active_cycles, idle_power,
                          n_pes, mean_residency)
    return (base + e_timeout_poll * timed_out_levels
            + e_abandon * abandoned_pes)


# ---------------------------------------------------------------------------
# Independent numpy oracle (test-only).
# ---------------------------------------------------------------------------

def _count_events(schedule, placement, cfg: TeraPoolConfig,
                  model: EnergyModel) -> tuple:
    """Explicit per-event counting loops — deliberately dumb and
    closed-form-free, the independent cross-check of
    :func:`schedule_energy_constants` (float64, rounded once)."""
    n = schedule.n_pes
    active = 0.0
    traffic = 0.0
    if getattr(schedule, "hw", False):
        for _ in range(n):
            active += cfg.hw_entry_instr
        for lvl, m, _ in _level_counts(schedule):
            for _ in range(m):
                traffic += model.e_hw_signal + model.e_hw_hop * lvl.latency
    else:
        for _ in range(n):
            active += cfg.instr_per_level
        for li, (lvl, m, count) in enumerate(_level_counts(schedule)):
            for c in range(count):
                lat = (placement.latencies[li][c]
                       if placement is not None else lvl.latency)
                for _ in range(lvl.group_size):
                    traffic += model.e_amo_issue + model.e_amo_hop * lat
            for _ in range(count):
                active += cfg.instr_per_level
    wakeup = model.e_wakeup_write
    for _ in range(n):
        wakeup += model.e_wakeup_line
    if model.sleep == "wfi":
        for _ in range(n - 1):
            wakeup += model.e_wfi_wake
    static = model.e_instr * active + traffic + wakeup
    return np.float32(static), np.float32(active)


def _episode_exit(arr: np.ndarray, schedule, cfg: TeraPoolConfig) -> float:
    """Unplaced episode walk in numpy, op-for-op the float32 sequence of
    :func:`repro.core.barrier_sim.simulate_reference` (sort, max-plus
    service scan, per-level latency + bookkeeping, wakeup)."""
    hw = bool(getattr(schedule, "hw", False))
    entry = cfg.hw_entry_instr if hw else cfg.instr_per_level
    svc = np.float32(0.0 if hw else cfg.bank_service_cycles)
    instr = np.float32(0.0 if hw else cfg.instr_per_level)
    ready = arr.astype(np.float32) + np.float32(entry)
    for lvl in schedule.levels:
        a = np.sort(ready.reshape((-1, lvl.group_size)), axis=-1)
        j = np.arange(a.shape[-1], dtype=np.float32) * svc
        start = np.maximum.accumulate(a - j, axis=-1) + j
        done = start[..., -1] + np.float32(lvl.latency)
        ready = done + instr
    return float(ready[0] + np.float32(cfg.wakeup_cycles))


def energy_reference(arrivals, schedule, cfg: TeraPoolConfig = DEFAULT,
                     placement=None,
                     model: EnergyModel = DEFAULT_ENERGY) -> jnp.ndarray:
    """Independent numpy energy oracle for one barrier episode (or a
    leading batch): explicit event-counting loops for the static part,
    an explicit per-episode queue walk (per-BANK queues when a
    placement is given) for the exit times, and the shared
    :func:`episode_energy` formula on top.  Pure python/numpy episode
    loops — test-only.
    """
    arr = np.asarray(arrivals, np.float32)
    if arr.shape[-1] != schedule.n_pes:
        raise ValueError(
            f"arrivals has {arr.shape[-1]} PEs, schedule expects "
            f"{schedule.n_pes}")
    n = schedule.n_pes
    batch = arr.shape[:-1]
    flat = arr.reshape((-1, n))

    static, active = _count_events(schedule, placement, cfg, model)
    idle = np.float32(model.idle_power)

    if placement is None:
        exits = np.asarray([_episode_exit(a, schedule, cfg) for a in flat],
                           np.float32)
    else:
        from .placement import _placed_episode
        exits = np.asarray(
            [_placed_episode(a, schedule, placement, cfg) for a in flat],
            np.float32) + np.float32(cfg.wakeup_cycles)

    # The residency mean mirrors the cores' reduction (same values in,
    # same jnp.mean out) so the final f32 ops agree bit for bit.
    resid = jnp.mean(jnp.asarray(exits[:, None] - flat), axis=-1)
    energy = episode_energy(jnp.float32(static), jnp.float32(active),
                            jnp.float32(idle), n, resid)
    return jnp.asarray(energy).reshape(batch)
