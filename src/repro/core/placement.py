"""Bank-aware counter placement: WHERE every barrier counter lives.

The paper places barrier counters "local to contiguous PE blocks"
(Sec. 5); the seed model reduced that to one span-derived latency per
tree level (``topology.access_latency``), so co-located counters never
contended and the tuner could not reason about placement at all.  The
MemPool/TeraPool interconnect studies (Cavalcante et al., Riedel et
al.) show bank *conflicts* — not just hop latency — dominate shared-L1
atomics, so this module makes the counter -> bank mapping an explicit,
tunable design axis:

* :class:`CounterPlacement` — for every counter of every tree level,
  the concrete L1 bank it occupies plus the locality-class latency its
  accessors pay (derived from ``TeraPoolConfig.span_bank_latency``, not
  the span heuristic).
* Strategies (:func:`place_counters`):
    - ``leaf_local``       — the paper's Sec. 5 policy: each counter in
      the first bank of its span's first PE.  Distinct banks, minimal
      latency; reproduces the legacy 1/3/5 per-level latencies
      bit-for-bit (the backward-compat oracle).
    - ``tile_interleaved`` — counters allocated round-robin across the
      Tiles' banks (word-interleaved heap allocation): conflict-free
      but mostly cluster-class latency.
    - ``group_hub``        — every counter inside a Group lands on that
      Group's hub bank: compact notification region, heavy same-bank
      contention among sibling counters.
    - ``central``          — all counters on bank 0: the degenerate
      maximum-contention corner.
* :func:`explicit_placement` — per-level ``(offset, stride)`` bank
  encoding, the raw knob the tuner (and tests) can drive directly:
  counter ``j`` of level ``l`` sits at ``(offset[l] + j * stride[l])
  % n_banks``.
* :func:`simulate_placed_reference` — an independent numpy oracle that
  walks explicit per-bank request queues; the scanned simulator's
  per-bank serialization is validated against it
  (tests/test_placement.py).

Sibling counters mapped to one bank *contend*: their atomics enter the
same single-ported service queue, so both simulator cores serialize
requests per bank rather than per counter (see
:func:`repro.core.barrier_sim._telescope_core`, the shrinking-width
production core, and :func:`repro.core.barrier_sim._scan_core`, its
full-width oracle — the per-bank-queue semantics are identical and
both are validated against :func:`simulate_placed_reference`).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from .barrier import BarrierSchedule
from .topology import DEFAULT, TeraPoolConfig

# The named strategy set the tuner sweeps by default.
STRATEGIES: Tuple[str, ...] = ("leaf_local", "tile_interleaved",
                               "group_hub", "central")


@dataclasses.dataclass(frozen=True)
class CounterPlacement:
    """Concrete bank assignment for every counter of one schedule.

    ``banks[l][j]`` is the bank holding counter ``j`` of level ``l``
    (counter ``j`` serves the contiguous original-PE span
    ``[j * span_l, (j+1) * span_l)``); ``latencies[l][j]`` is the
    locality-class access latency its farthest accessor pays.  Frozen
    tuples keep the object hashable so placed level tables cache like
    plain ones.
    """

    strategy: str
    banks: tuple       # tuple[tuple[int, ...], ...], one row per level
    latencies: tuple   # tuple[tuple[int, ...], ...], same shape

    @property
    def n_levels(self) -> int:
        return len(self.banks)

    def shared_bank_counters(self) -> Tuple[int, ...]:
        """Per level, how many counters share a bank with a sibling —
        the static contention exposure of this placement."""
        out = []
        for row in self.banks:
            uniq, counts = np.unique(np.asarray(row), return_counts=True)
            out.append(int(counts[counts > 1].sum()))
        return tuple(out)


def _counter_spans(schedule: BarrierSchedule) -> List[Tuple[int, int]]:
    """Per level: (span, n_counters)."""
    return [(lvl.span, schedule.n_pes // lvl.span)
            for lvl in schedule.levels]


def _banks_leaf_local(schedule: BarrierSchedule,
                      cfg: TeraPoolConfig) -> List[List[int]]:
    bf = cfg.banking_factor
    return [[j * span * bf for j in range(count)]
            for span, count in _counter_spans(schedule)]


def _banks_tile_interleaved(schedule: BarrierSchedule,
                            cfg: TeraPoolConfig) -> List[List[int]]:
    # Round-robin across the Tiles covered by the barrier, then across
    # each Tile's banks with word stride — the bank pattern of counters
    # allocated sequentially from an interleaved heap.
    n_tiles = max(1, schedule.n_pes // cfg.pes_per_tile)
    local_banks = schedule.n_pes * cfg.banking_factor
    return [[((j % n_tiles) * cfg.banks_per_tile
              + (j // n_tiles) * cfg.banking_factor) % local_banks
             for j in range(count)]
            for _, count in _counter_spans(schedule)]


def _banks_group_hub(schedule: BarrierSchedule,
                     cfg: TeraPoolConfig) -> List[List[int]]:
    # Every counter lands on the hub bank (bank 0) of the Group holding
    # its span's first PE: a compact per-Group synchronization region.
    return [[(j * span // cfg.pes_per_group) * cfg.banks_per_group
             for j in range(count)]
            for span, count in _counter_spans(schedule)]


def _banks_central(schedule: BarrierSchedule,
                   cfg: TeraPoolConfig) -> List[List[int]]:
    return [[0] * count for _, count in _counter_spans(schedule)]


_STRATEGY_FNS: Dict[str, Callable] = {
    "leaf_local": _banks_leaf_local,
    "tile_interleaved": _banks_tile_interleaved,
    "group_hub": _banks_group_hub,
    "central": _banks_central,
}


def derive_latencies(schedule: BarrierSchedule, banks: Sequence[Sequence[int]],
                     cfg: TeraPoolConfig = DEFAULT) -> tuple:
    """Per-counter access latency from PE <-> bank locality classes.

    Counter ``j`` of level ``l`` is reached by the survivors of its
    span ``[j * span_l, (j+1) * span_l)``; the level's cost charges the
    farthest accessor's class (``cfg.span_bank_latency``), the exact
    generalization of the legacy one-latency-per-level model.
    """
    rows = []
    for (span, count), brow in zip(_counter_spans(schedule), banks):
        if len(brow) != count:
            raise ValueError(
                f"level with span {span} has {count} counters, placement "
                f"maps {len(brow)}")
        rows.append(tuple(cfg.span_bank_latency(j * span, span, int(b))
                          for j, b in enumerate(brow)))
    return tuple(rows)


@functools.lru_cache(maxsize=None)
def place_counters(schedule: BarrierSchedule, strategy: str = "leaf_local",
                   cfg: TeraPoolConfig = DEFAULT) -> CounterPlacement:
    """Map every counter of ``schedule`` to a bank under a named
    strategy and derive the per-counter access latencies.  Cached per
    (schedule, strategy, cfg) — repeated tuner sweeps over the same
    design space pay the per-counter Python derivation once.

    Partial (subset) barriers are placed in subset-relative bank
    coordinates: the 256-PE FFT subsets are span-aligned, so relative
    locality classes equal absolute ones.
    """
    try:
        fn = _STRATEGY_FNS[strategy]
    except KeyError:
        raise ValueError(
            f"unknown placement strategy {strategy!r}; "
            f"choose from {STRATEGIES}") from None
    banks = fn(schedule, cfg)
    return CounterPlacement(
        strategy=strategy,
        banks=tuple(tuple(int(b) for b in row) for row in banks),
        latencies=derive_latencies(schedule, banks, cfg))


def explicit_placement(schedule: BarrierSchedule,
                       bank_offsets: Sequence[int],
                       bank_strides: Sequence[int] | None = None,
                       cfg: TeraPoolConfig = DEFAULT) -> CounterPlacement:
    """Explicit per-level bank-offset encoding: counter ``j`` of level
    ``l`` sits at ``(bank_offsets[l] + j * bank_strides[l]) % n_banks``.

    ``bank_strides`` defaults to the banking factor (consecutive
    counters in consecutive word-interleaved banks); a stride of 0
    deliberately piles every counter of a level onto one bank.
    """
    n_levels = schedule.n_levels
    if len(bank_offsets) != n_levels:
        raise ValueError(
            f"schedule has {n_levels} levels, got {len(bank_offsets)} "
            f"bank offsets")
    if bank_strides is None:
        bank_strides = [cfg.banking_factor] * n_levels
    if len(bank_strides) != n_levels:
        raise ValueError(
            f"schedule has {n_levels} levels, got {len(bank_strides)} "
            f"bank strides")
    banks = [[(int(off) + j * int(stride)) % cfg.n_banks
              for j in range(count)]
             for (_, count), off, stride in zip(_counter_spans(schedule),
                                                bank_offsets, bank_strides)]
    return CounterPlacement(
        strategy="explicit",
        banks=tuple(tuple(row) for row in banks),
        latencies=derive_latencies(schedule, banks, cfg))


def all_placements(schedule: BarrierSchedule,
                   strategies: Sequence[str] = STRATEGIES,
                   cfg: TeraPoolConfig = DEFAULT) -> List[CounterPlacement]:
    """One :class:`CounterPlacement` per named strategy."""
    return [place_counters(schedule, s, cfg) for s in strategies]


# ---------------------------------------------------------------------------
# Independent per-bank-queue oracle (numpy, test-only).
# ---------------------------------------------------------------------------

def _placed_episode(arr: np.ndarray, schedule: BarrierSchedule,
                    pl: CounterPlacement, cfg: TeraPoolConfig) -> float:
    """One episode via explicit per-bank queues; returns the final
    survivor's ready time (float32 arithmetic, matching the scanned
    core op-for-op so equivalence is exact)."""
    svc = np.float32(cfg.bank_service_cycles)
    instr = np.float32(cfg.instr_per_level)
    ready = arr.astype(np.float32) + instr
    for lvl, brow, lrow in zip(schedule.levels, pl.banks, pl.latencies):
        g = lvl.group_size
        m = ready.shape[0]
        grp = np.arange(m) // g
        bank = np.asarray(brow, np.int64)[grp]
        done = np.empty(m // g, np.float32)
        for b in np.unique(bank):
            sel = np.nonzero(bank == b)[0]
            order = sel[np.argsort(ready[sel], kind="stable")]
            a = ready[order]
            r = np.arange(len(a), dtype=np.float32) * svc
            s = np.maximum.accumulate(a - r) + r   # per-request service start
            for gi in np.unique(grp[order]):
                mask = grp[order] == gi
                done[gi] = np.float32(s[mask].max()
                                      + np.float32(lrow[gi]))
        ready = done + instr
    return float(ready[0])


def simulate_placed_reference(arrivals, schedule: BarrierSchedule,
                              placement: CounterPlacement,
                              cfg: TeraPoolConfig = DEFAULT):
    """Placement-aware equivalence oracle for the scanned core.

    Walks the tree level by level with explicit per-bank request
    queues: all atomics mapped to one bank — across sibling counters —
    serialize in arrival order at ``bank_service_cycles`` apiece, and
    each counter's last arriver proceeds once its own request is
    serviced.  Pure numpy, per-episode Python loops: use only in tests
    and spot checks.
    """
    from .barrier_sim import BarrierResult
    from .energy import (DEFAULT_ENERGY, episode_energy,
                         schedule_energy_constants)
    arr = np.asarray(arrivals, np.float32)
    if arr.shape[-1] != schedule.n_pes:
        raise ValueError(
            f"arrivals has {arr.shape[-1]} PEs, schedule expects "
            f"{schedule.n_pes}")
    batch = arr.shape[:-1]
    flat = arr.reshape((-1, arr.shape[-1]))
    wake = np.float32(cfg.wakeup_cycles)
    exits = np.asarray(
        [_placed_episode(a, schedule, placement, cfg) for a in flat],
        np.float32) + wake
    exit_time = exits.reshape(batch)
    last = np.max(flat, axis=-1).reshape(batch)
    # Same values in, same jnp.mean reduction as the cores — so the
    # residency-derived energy column agrees to the same precision as
    # the exit times themselves.
    resid = jnp.mean(jnp.asarray(exits[:, None] - flat),
                     axis=-1).reshape(batch)
    stat, act, idle = schedule_energy_constants(
        schedule, placement, cfg, DEFAULT_ENERGY)
    zeros = jnp.zeros(batch, jnp.int32)
    return BarrierResult(
        exit_time=jnp.asarray(exit_time),
        last_arrival=jnp.asarray(last),
        span_cycles=jnp.asarray(exit_time - last),
        mean_residency=resid,
        energy=episode_energy(jnp.float32(stat), jnp.float32(act),
                              jnp.float32(idle), schedule.n_pes, resid),
        completed=jnp.isfinite(jnp.asarray(exit_time)),
        abandoned_pes=zeros,
        timed_out_levels=zeros,
    )
