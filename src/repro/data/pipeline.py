"""Deterministic synthetic data pipeline.

Produces seeded, reproducible token batches with a next-token LM
structure (so loss curves are meaningful: the stream mixes Zipfian
unigrams with copy/induction patterns that a real model can learn).

Sharding contract: ``global_batch(step)`` is a pure function of
(seed, step), so every host can materialize exactly its own rows
without communication — host i of H loads rows [i*B/H, (i+1)*B/H).
Restart-safe by construction: the loader has no mutable state.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    seq_len: int = 1024
    global_batch: int = 8
    vocab_size: int = 32000
    zipf_alpha: float = 1.2
    copy_period: int = 64      # induction-pattern period


def _zipf_probs(v: int, alpha: float) -> np.ndarray:
    p = 1.0 / np.arange(1, v + 1) ** alpha
    return p / p.sum()


class SyntheticLM:
    """Stateless-by-step synthetic LM stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._probs = _zipf_probs(cfg.vocab_size, cfg.zipf_alpha)

    def batch(self, step: int, *, host_id: int = 0,
              host_count: int = 1) -> Dict[str, np.ndarray]:
        """The rows of global batch ``step`` owned by this host."""
        cfg = self.cfg
        if cfg.global_batch % host_count:
            raise ValueError("global_batch must divide across hosts")
        rows = cfg.global_batch // host_count
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, host_id]))
        toks = rng.choice(cfg.vocab_size, p=self._probs,
                          size=(rows, cfg.seq_len + 1)).astype(np.int32)
        # Periodic copying: positions t copy t - copy_period, giving the
        # model an induction signal.
        t = np.arange(cfg.seq_len + 1)
        mask = (t % cfg.copy_period) >= (cfg.copy_period // 2)
        src = np.maximum(t - cfg.copy_period // 2, 0)
        toks[:, mask] = toks[:, src[mask]]
        return {"tokens": toks[:, :-1],
                "targets": toks[:, 1:].copy()}

    def iterator(self, start_step: int = 0, *, host_id: int = 0,
                 host_count: int = 1) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step, host_id=host_id, host_count=host_count)
            step += 1


def batch_for_model(mcfg: ModelConfig, dcfg: DataConfig, step: int,
                    *, host_id: int = 0, host_count: int = 1
                    ) -> Dict[str, np.ndarray]:
    """Adapt the LM stream to a model family's input schema (audio
    frontends take frame embeddings; VLMs add stub patch embeddings)."""
    stream = SyntheticLM(dataclasses.replace(
        dcfg, vocab_size=min(dcfg.vocab_size, mcfg.vocab_size)))
    b = stream.batch(step, host_id=host_id, host_count=host_count)
    out: Dict[str, np.ndarray] = {"targets": b["targets"]}
    rows = b["tokens"].shape[0]
    if mcfg.frontend == "audio":
        rng = np.random.default_rng(
            np.random.SeedSequence([dcfg.seed, step, host_id, 7]))
        out["features"] = rng.standard_normal(
            (rows, dcfg.seq_len, mcfg.d_model)).astype(np.float32) * 0.02
    else:
        out["tokens"] = b["tokens"]
        if mcfg.frontend == "vision":
            rng = np.random.default_rng(
                np.random.SeedSequence([dcfg.seed, step, host_id, 11]))
            out["img_embeds"] = rng.standard_normal(
                (rows, mcfg.n_frontend_tokens, mcfg.d_model)
            ).astype(np.float32) * 0.02
    return out
