"""Deterministic synthetic data pipeline."""
from .pipeline import DataConfig, SyntheticLM, batch_for_model

__all__ = ["DataConfig", "SyntheticLM", "batch_for_model"]
