"""Launcher: meshes, sharded step builders, dry-run and roofline."""
from . import hlo_analysis, mesh, roofline, steps
from .mesh import make_production_mesh, make_smoke_mesh

__all__ = ["hlo_analysis", "make_production_mesh", "make_smoke_mesh",
           "mesh", "roofline", "steps"]
