"""Mesh construction for the production pod slice.

``make_production_mesh`` is a FUNCTION (not a module constant) so
importing this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then builds the mesh.
"""
from __future__ import annotations

from typing import Tuple

import jax


def _mk(shape, axes):
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):
        # jax < 0.5: no AxisType / axis_types kwarg; all axes are Auto.
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_smoke_mesh():
    """1x1 mesh with the production axis names for CPU tests."""
    return _mk((1, 1), ("data", "model"))


def mesh_context(mesh):
    """``jax.set_mesh(mesh)`` where available; on jax < 0.5 the Mesh
    object itself is the (legacy) context manager."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def manual_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes handled manually by shard_map (everything
    except the auto TP axis)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def data_axes(mesh) -> Tuple[str, ...]:
    """FSDP axes: the intra-pod data axes (excludes ``pod``)."""
    return tuple(a for a in mesh.axis_names
                 if a != "model" and a != "pod")


def pod_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a == "pod")


def axis_size(mesh, axes) -> int:
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s
