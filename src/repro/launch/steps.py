"""Sharded step builders: train_step / prefill_step / decode_step.

Pure-GSPMD distribution (DESIGN.md §5): parameters carry
(FSDP x TP) NamedShardings derived from the ParamDef registry; XLA's
SPMD partitioner materializes the per-layer gathers inside the scanned
stack and the reduce-scatters in the backward pass.  The paper's
synchronization schedules map onto the sharding plan:

* flat (central-counter): ``SyncConfig.fsdp=False`` — parameters
  replicated over the data axes, gradients synchronized by ONE
  full-size all-reduce spanning every chip;
* hierarchical (k-ary tree): ``SyncConfig.fsdp=True`` — ZeRO-3 shards
  over ``data``; backward reduce-scatters shard-sized partial sums
  intra-pod and only shards cross the ``pod`` axis;
* radix-k: factored data axes (core/collectives.make_factored_mesh)
  stage the reduction per tree level.

All step builders must be lowered/executed inside
``with jax.set_mesh(mesh):`` so activation sharding constraints
resolve.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core import collectives
from ..core.collectives import SyncConfig
from ..models import transformer
from ..models.config import ModelConfig, ShapeCell
from ..models.layers import ParamDef, constrain
from .. import optim
from . import mesh as mesh_mod


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


# ---------------------------------------------------------------------------
# Sharding plan from ParamDef trees.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    full: Any       # pytree of PartitionSpec
    scattered: Any  # pytree of bool: True if FSDP-sharded over data


def _leaf_spec(d: ParamDef, data_ax, data_size: int, model_size: int,
               fsdp_on: bool, tp_2d: bool = False):
    # TP entries only where the dim divides the model axis (a 32001-row
    # embedding or 504-class head stays TP-replicated).
    ent = [a if (a is None or d.shape[i] % model_size == 0) else None
           for i, a in enumerate(d.tp)]
    if tp_2d:
        # Serving 2D-TP: fold the data axes INTO the TP dim so weights
        # shard over every chip with NO per-layer gathers (decode stays
        # weight-streaming bound instead of interconnect bound).
        for i, a in enumerate(ent):
            if a == "model" and d.shape[i] % (model_size * data_size) == 0:
                ent[i] = ("model",) + tuple(data_ax)
        return P(*ent), False
    sharded = (fsdp_on and data_ax and d.fsdp_dim is not None
               and d.shape[d.fsdp_dim] % data_size == 0
               and d.shape[d.fsdp_dim] >= data_size)
    if sharded:
        assert ent[d.fsdp_dim] is None, (d, "tp/fsdp dim collision")
        ent[d.fsdp_dim] = data_ax if len(data_ax) > 1 else data_ax[0]
    return P(*ent), bool(sharded)


def make_plan(def_tree, mesh, fsdp_on: bool,
              tp_2d: bool = False) -> ShardingPlan:
    data_ax = mesh_mod.data_axes(mesh)
    data_size = mesh_mod.axis_size(mesh, data_ax)
    model_size = mesh.shape.get("model", 1)

    def pick(i):
        return jax.tree.map(
            lambda d: _leaf_spec(d, data_ax, data_size, model_size,
                                 fsdp_on, tp_2d)[i],
            def_tree, is_leaf=_is_def)

    return ShardingPlan(full=pick(0), scattered=pick(1))


def tree_sds(def_tree, plan_full, mesh):
    """ShapeDtypeStruct stand-ins with shardings (no allocation)."""
    return jax.tree.map(
        lambda d, s: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype),
                                          sharding=NamedSharding(mesh, s)),
        def_tree, plan_full, is_leaf=_is_def)


def shardings_of(plan_full, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), plan_full)


def _dp_axes(mesh) -> tuple:
    return mesh_mod.manual_axes(mesh)   # ("pod","data"...) — batch axes


def _dp_entry(mesh, shardable: bool = True):
    dp = _dp_axes(mesh)
    if not dp or not shardable:
        return None
    return dp if len(dp) > 1 else dp[0]


# ---------------------------------------------------------------------------
# Train step.
# ---------------------------------------------------------------------------

METRIC_KEYS = ("loss", "ce", "aux", "mtp")


def build_train_step(cfg: ModelConfig, mesh, *,
                     sync: SyncConfig = collectives.HIERARCHICAL,
                     opt_cfg: Optional[optim.OptConfig] = None):
    """Returns (jitted_step, artifacts); step(params, opt_state, batch)
    -> (params, opt_state, metrics).  Call within jax.set_mesh(mesh)."""
    opt_cfg = opt_cfg or optim.OptConfig.from_model(cfg)
    defs = transformer.param_defs(cfg)
    sdefs = optim.state_defs(defs, opt_cfg)
    plan = make_plan(defs, mesh, sync.fsdp)
    splan = make_plan(sdefs, mesh, sync.fsdp)
    dp = _dp_entry(mesh)
    mkeys = METRIC_KEYS if cfg.use_mtp else METRIC_KEYS[:3]

    def step(params, opt_state, batch):
        gb = jax.tree.leaves(batch)[0].shape[0]
        n_micro = min(cfg.micro_batches, gb)

        def to_micro(x):
            x = x.reshape((n_micro, gb // n_micro) + x.shape[1:])
            return constrain(x, None, dp, *([None] * (x.ndim - 2)))

        micro = jax.tree.map(to_micro, batch)

        def local_loss(p, mb):
            mb = jax.tree.map(
                lambda x: constrain(x, dp, *([None] * (x.ndim - 1))), mb)
            loss, metrics = transformer.loss_fn(p, cfg, mb)
            return loss, tuple(metrics[k] for k in mkeys)

        def micro_step(carry, mb):
            g_acc, m_acc = carry
            (_, metrics), grads = jax.value_and_grad(
                local_loss, has_aux=True)(params, mb)
            g_acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype),
                                 g_acc, grads)
            return (g_acc,
                    tuple(a + m for a, m in zip(m_acc, metrics))), None

        accum_dtype = jnp.dtype(cfg.grad_accum_dtype)
        g0 = jax.tree.map(
            lambda p, s: constrain(
                jnp.zeros(p.shape, accum_dtype), *s),
            params, plan.full)
        m0 = tuple(jnp.zeros((), jnp.float32) for _ in mkeys)
        (grads, msum), _ = jax.lax.scan(micro_step, (g0, m0), micro)

        scale = 1.0 / n_micro
        grads = jax.tree.map(
            lambda g, s: constrain(g * jnp.asarray(scale, g.dtype), *s),
            grads, plan.full)
        nsq = optim.global_norm_sq(grads)
        new_params, new_opt = optim.update(grads, opt_state, params,
                                           opt_cfg, norm_sq=nsq)
        mtree = {k: v / n_micro for k, v in zip(mkeys, msum)}
        mtree["grad_norm"] = jnp.sqrt(nsq)
        return new_params, new_opt, mtree

    fn = jax.jit(
        step,
        in_shardings=(shardings_of(plan.full, mesh),
                      shardings_of(splan.full, mesh),
                      _batch_shardings(cfg, mesh, "train")),
        out_shardings=(shardings_of(plan.full, mesh),
                       shardings_of(splan.full, mesh), None),
        donate_argnums=(0, 1))
    art = {"defs": defs, "sdefs": sdefs, "plan": plan, "splan": splan,
           "opt_cfg": opt_cfg}
    return fn, art


# ---------------------------------------------------------------------------
# Batch shardings / cache specs.
# ---------------------------------------------------------------------------

def _batch_specs(cfg: ModelConfig, mesh, kind: str,
                 shardable: bool = True) -> Dict[str, Any]:
    dpe = _dp_entry(mesh, shardable)

    def spec(nd):
        return P(*([dpe] + [None] * (nd - 1)))

    out: Dict[str, Any] = {}
    if cfg.frontend == "audio":
        out["features"] = spec(3)
    else:
        out["tokens"] = spec(2)
        if cfg.frontend == "vision" and kind != "decode":
            out["img_embeds"] = spec(3)
    if kind == "train":
        out["targets"] = spec(2)
    return out


def _batch_shardings(cfg, mesh, kind, shardable: bool = True):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        _batch_specs(cfg, mesh, kind, shardable))


_CACHE_MODEL_DIM = {  # leaf name -> dim carrying the "model" sharding
    "k": 2, "v": 2, "positions": 2,   # (L, B, S, Hk, D) / (L, B, S)
    "ckv": 2, "kpe": 2,               # (L, B, S, r)
    "conv": 3,                        # (L, B, K-1, di)
    "state": 2,                       # (L, B, di, n)
}


def _cache_leaf_spec(path, leaf, dp, model_size: int):
    name = None
    for entry in reversed(path):
        n = getattr(entry, "name", None)
        if n is None:
            n = getattr(entry, "key", None)
        if isinstance(n, str) and n in _CACHE_MODEL_DIM:
            name = n
            break
    ent = [None] * leaf.ndim
    if dp:
        ent[1] = dp
    if name is not None:
        dim = _CACHE_MODEL_DIM[name]
        if dim < leaf.ndim and leaf.shape[dim] % model_size == 0:
            ent[dim] = "model"
    return P(*ent)


def cache_specs(caches_shape_tree, dp, model_size: int):
    """Spec tree mirroring an init_caches result (stacked (L,B,...))."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _cache_leaf_spec(path, leaf, dp, model_size),
        caches_shape_tree)


def constrain_caches(caches, mesh):
    """Model-axis constraints on freshly created caches."""
    model_size = mesh.shape.get("model", 1)

    def leaf(path, x):
        spec = _cache_leaf_spec(path, x, None, model_size)
        if all(s is None for s in spec):
            return x
        return constrain(x, *spec)

    return jax.tree_util.tree_map_with_path(leaf, caches)


# ---------------------------------------------------------------------------
# Serve steps.
# ---------------------------------------------------------------------------

def build_prefill_step(cfg: ModelConfig, mesh, *, batch: int,
                       seq_len: int, fsdp: Optional[bool] = None):
    """Prefill: encode ``seq_len`` tokens -> last logits (+ caches)."""
    fsdp = cfg.fsdp_serve if fsdp is None else fsdp
    defs = transformer.param_defs(cfg)
    plan = make_plan(defs, mesh, fsdp and not cfg.serve_2d_tp,
                     tp_2d=cfg.serve_2d_tp)
    dp_axes = _dp_axes(mesh)
    n_dp = mesh_mod.axis_size(mesh, dp_axes)
    shardable = batch % max(n_dp, 1) == 0 and n_dp > 1
    dpe = _dp_entry(mesh, shardable)
    decoder = cfg.family != "encoder"

    def fn(params, batch_in):
        batch_in = jax.tree.map(
            lambda x: constrain(x, dpe, *([None] * (x.ndim - 1))),
            batch_in)
        caches = None
        if decoder:
            caches = transformer.init_caches(cfg, batch, seq_len)
            caches = jax.tree.map(
                lambda x: constrain(x, None, dpe,
                                    *([None] * (x.ndim - 2))), caches)
            caches = constrain_caches(caches, mesh)
        logits, new_caches, _, _ = transformer.forward(
            params, cfg, batch_in, caches=caches, remat=False)
        last = logits if cfg.family == "encoder" else logits[:, -1:]
        return (last, new_caches) if decoder else last

    fn_j = jax.jit(fn, in_shardings=(
        shardings_of(plan.full, mesh),
        _batch_shardings(cfg, mesh, "prefill", shardable)))
    return fn_j, {"defs": defs, "plan": plan}


def build_decode_step(cfg: ModelConfig, mesh, *, batch: int,
                      max_len: int, fsdp: Optional[bool] = None):
    """One decode step against pre-filled caches."""
    fsdp = cfg.fsdp_serve if fsdp is None else fsdp
    defs = transformer.param_defs(cfg)
    plan = make_plan(defs, mesh, fsdp and not cfg.serve_2d_tp,
                     tp_2d=cfg.serve_2d_tp)
    dp_axes = _dp_axes(mesh)
    n_dp = mesh_mod.axis_size(mesh, dp_axes)
    model_size = mesh.shape.get("model", 1)
    shardable = batch % max(n_dp, 1) == 0 and n_dp > 1
    dpe = _dp_entry(mesh, shardable)
    if cfg.serve_2d_tp:
        # 2D-TP decode: weights shard over (model x data); ACTIVATIONS
        # must therefore be batch-replicated (they are tiny at S=1) —
        # only the KV cache keeps its batch sharding.
        cfg = dataclasses.replace(cfg, batch_axes=(),
                                  tp_axes=("model", "data"))

    def fn(params, caches, tokens, pos):
        logits, new_caches, _, _ = transformer.forward(
            params, cfg, {"tokens": tokens}, caches=caches,
            decode_pos=pos, remat=False)
        return logits, new_caches

    caches_shapes = jax.eval_shape(
        lambda: transformer.init_caches(cfg, batch, max_len))
    cspecs = cache_specs(caches_shapes, dpe, model_size)
    csh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs)
    tok_dpe = None if cfg.serve_2d_tp else dpe
    tok_sh = NamedSharding(mesh, P(tok_dpe, None))
    pos_sh = NamedSharding(mesh, P(tok_dpe))
    fn_j = jax.jit(fn,
                   in_shardings=(shardings_of(plan.full, mesh), csh,
                                 tok_sh, pos_sh),
                   out_shardings=(None, csh),
                   donate_argnums=(1,))
    return fn_j, {"defs": defs, "plan": plan,
                  "cache_shapes": caches_shapes, "cache_shardings": csh}


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct stand-ins, no allocation).
# ---------------------------------------------------------------------------

def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype),
                                sharding=NamedSharding(mesh, spec))


def batch_sds(cfg: ModelConfig, shape: ShapeCell, mesh, kind: str):
    dp_axes = _dp_axes(mesh)
    n_dp = mesh_mod.axis_size(mesh, dp_axes)
    shardable = shape.global_batch % max(n_dp, 1) == 0 and n_dp > 1
    specs = _batch_specs(cfg, mesh, kind, shardable)
    gb = shape.global_batch
    s = shape.seq_len if kind != "decode" else 1
    out: Dict[str, Any] = {}
    for k, spec in specs.items():
        if k in ("tokens", "targets"):
            out[k] = _sds((gb, s), "int32", mesh, spec)
        elif k == "features":
            out[k] = _sds((gb, s, cfg.d_model), "bfloat16", mesh, spec)
        elif k == "img_embeds":
            out[k] = _sds((gb, cfg.n_frontend_tokens, cfg.d_model),
                          "bfloat16", mesh, spec)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeCell, mesh, *,
                sync: SyncConfig = collectives.HIERARCHICAL,
                opt_cfg: Optional[optim.OptConfig] = None):
    """Full argument SDS tuple for the cell's step function."""
    defs = transformer.param_defs(cfg)
    if shape.kind == "train":
        opt_cfg = opt_cfg or optim.OptConfig.from_model(cfg)
        plan = make_plan(defs, mesh, sync.fsdp)
        sdefs = optim.state_defs(defs, opt_cfg)
        splan = make_plan(sdefs, mesh, sync.fsdp)
        return (tree_sds(defs, plan.full, mesh),
                tree_sds(sdefs, splan.full, mesh),
                batch_sds(cfg, shape, mesh, "train"))
    plan = make_plan(defs, mesh, cfg.fsdp_serve and not cfg.serve_2d_tp,
                     tp_2d=cfg.serve_2d_tp)
    params = tree_sds(defs, plan.full, mesh)
    if shape.kind == "prefill":
        return (params, batch_sds(cfg, shape, mesh, "prefill"))
    # decode
    dp_axes = _dp_axes(mesh)
    n_dp = mesh_mod.axis_size(mesh, dp_axes)
    model_size = mesh.shape.get("model", 1)
    shardable = shape.global_batch % max(n_dp, 1) == 0 and n_dp > 1
    dpe = _dp_entry(mesh, shardable)
    caches_shapes = jax.eval_shape(
        lambda: transformer.init_caches(cfg, shape.global_batch,
                                        shape.seq_len))
    cspecs = cache_specs(caches_shapes, dpe, model_size)
    caches = jax.tree.map(
        lambda sds, spec: _sds(sds.shape, sds.dtype, mesh, spec),
        caches_shapes, cspecs)
    tok_dpe = None if cfg.serve_2d_tp else dpe
    toks = _sds((shape.global_batch, 1), "int32", mesh, P(tok_dpe, None))
    pos = _sds((shape.global_batch,), "int32", mesh, P(tok_dpe))
    return (params, caches, toks, pos)
