"""Post-SPMD HLO analysis: FLOPs, HBM bytes and collective bytes with
while-loop trip-count multipliers.

XLA's built-in ``cost_analysis`` counts a ``while`` body ONCE, which
undercounts scanned-layer models by ~L x micro_batches.  This module
parses ``compiled.as_text()`` (the partitioned per-device module):

* builds a name -> (dtype, shape) map for every instruction,
* infers each while loop's trip count from the constants in its
  condition computation and propagates multipliers through nesting,
* FLOPs: 2 * prod(result) * contracted-dims for every dot/convolution
  (the >99% term for transformer workloads),
* HBM bytes: lhs+rhs+result bytes of every dot, trip-multiplied — the
  weight-streaming + matmul-activation traffic that dominates TPU HBM
  pressure.  (Counting every op boundary massively overcounts on the
  CPU backend, whose fusion decisions differ from TPU's; the dot proxy
  is the documented, consistent estimator used for the roofline's
  memory term.)
* collective bytes: ring-algorithm wire bytes per device for
  all-reduce / all-gather / reduce-scatter / all-to-all /
  collective-permute, with replica-group sizes parsed per op.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "iota"}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
# Type strings may embed /*index=N*/ comments (scheduled tuple types);
# the opcode is the first ``word(`` after the type (comments/layouts
# contain no parentheses).
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[a-z0-9]+\[.*?)\s*"
    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+(?:\([^)]*\))?.*{\s*$")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "f32", []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclasses.dataclass
class Instruction:
    name: str
    type_str: str
    opcode: str
    rest: str
    comp: str


def parse_instructions(hlo: str) -> Tuple[List[Instruction],
                                          Dict[str, List[str]]]:
    """Returns (instructions, computation -> instruction names)."""
    instrs: List[Instruction] = []
    comp = "?"
    comp_members: Dict[str, List[str]] = defaultdict(list)
    for line in hlo.splitlines():
        if not line:
            continue
        # Computation headers start at column 0 and end with "{";
        # instructions are indented.
        if not line[0].isspace():
            if line.rstrip().endswith("{"):
                mc = _COMP_RE.match(line)
                if mc:
                    comp = mc.group(1)
            continue
        md = _DEF_RE.match(line)
        if md:
            name, tstr, opcode, rest = md.groups()
            instrs.append(Instruction(name, tstr, opcode, rest, comp))
            comp_members[comp].append(name)
    return instrs, comp_members


def _while_multipliers(instrs: List[Instruction]) -> Dict[str, float]:
    """computation name -> execution-count multiplier."""
    # Constants per computation (for trip-count inference).
    const_by_comp: Dict[str, List[int]] = defaultdict(list)
    for ins in instrs:
        if ins.opcode == "constant" and "s32[]" in ins.type_str:
            m = re.search(r"constant\((-?\d+)\)", "constant(" + ins.rest)
            if m:
                const_by_comp[ins.comp].append(int(m.group(1)))

    # while ops: (defining comp, body comp, condition comp)
    whiles = []
    for ins in instrs:
        if ins.opcode == "while":
            mb = re.search(r"body=%?([\w.\-]+)", ins.rest)
            mcnd = re.search(r"condition=%?([\w.\-]+)", ins.rest)
            if mb and mcnd:
                whiles.append((ins.comp, mb.group(1), mcnd.group(1)))

    mult: Dict[str, float] = defaultdict(lambda: 1.0)
    # Fixpoint: nested whiles inherit their parent's multiplier.
    for _ in range(8):
        changed = False
        for parent, body, cond in whiles:
            trips = max([c for c in const_by_comp.get(cond, []) if c > 0],
                        default=1)
            new = mult[parent] * trips
            for c in (body, cond):
                if mult[c] != new:
                    mult[c] = new
                    changed = True
        if not changed:
            break
    return dict(mult)


def _call_multipliers(instrs: List[Instruction],
                      mult: Dict[str, float]) -> Dict[str, float]:
    """Extend multipliers through call/fusion/to_apply edges."""
    out = defaultdict(lambda: 1.0, mult)
    edges = []
    for ins in instrs:
        for key in ("calls=", "to_apply="):
            for m in re.finditer(key + r"%?([\w.\-]+)", ins.rest):
                edges.append((ins.comp, m.group(1)))
    for _ in range(8):
        changed = False
        for parent, child in edges:
            if out[child] < out[parent]:
                out[child] = out[parent]
                changed = True
        if not changed:
            break
    return out


def _group_size(rest: str, default: int = 1) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", rest)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


@dataclasses.dataclass
class HLOStats:
    flops: float = 0.0              # per device, trip-multiplied
    hbm_bytes: float = 0.0          # per device
    collective_bytes: float = 0.0   # wire bytes per device
    collective_by_type: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    collective_count: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    dot_flops_by_comp: Dict[str, float] = dataclasses.field(
        default_factory=dict)


def analyze(hlo: str) -> HLOStats:
    instrs, _ = parse_instructions(hlo)
    shapes: Dict[str, str] = {i.name: i.type_str for i in instrs}
    mult = _call_multipliers(instrs, _while_multipliers(instrs))
    stats = HLOStats(collective_by_type=defaultdict(float),
                     collective_count=defaultdict(int),
                     dot_flops_by_comp=defaultdict(float))

    for ins in instrs:
        k = mult.get(ins.comp, 1.0)
        # ---- FLOPs and HBM bytes from dots ----
        if ins.opcode == "dot":
            _, rdims = _first_shape(ins.type_str)
            ops = re.findall(r"%([\w.\-]+)", ins.rest)
            cdim = 1
            b = _shape_bytes(ins.type_str)
            mlc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
            if ops and mlc and ops[0] in shapes:
                _, lshape = _first_shape(shapes[ops[0]])
                for d in mlc.group(1).split(","):
                    if d and int(d) < len(lshape):
                        cdim *= lshape[int(d)]
            for opn in ops[:2]:
                if opn in shapes:
                    b += _shape_bytes(shapes[opn])
            f = 2.0 * math.prod(rdims or [1]) * cdim
            stats.flops += k * f
            stats.hbm_bytes += k * b
            stats.dot_flops_by_comp[ins.comp] += k * f
        elif ins.opcode == "convolution":
            _, rdims = _first_shape(ins.type_str)
            # rough: 2 * out * (in_ch * kernel_spatial) — parse window
            stats.flops += k * 2.0 * math.prod(rdims or [1]) * 8
            stats.hbm_bytes += k * _shape_bytes(ins.type_str) * 3

        # ---- collective bytes ----
        if ins.opcode in _COLLECTIVES:
            g = _group_size(ins.rest)
            rb = _shape_bytes(ins.type_str)
            if ins.opcode == "all-reduce":
                wire = 2.0 * rb * (g - 1) / max(g, 1)
            elif ins.opcode == "all-gather":
                wire = rb * (g - 1) / max(g, 1)
            elif ins.opcode == "reduce-scatter":
                wire = rb * (g - 1)          # operand = result * g
            elif ins.opcode == "all-to-all":
                wire = rb * (g - 1) / max(g, 1)
            else:  # collective-permute
                wire = rb
            stats.collective_bytes += k * wire
            stats.collective_by_type[ins.opcode] += k * wire
            stats.collective_count[ins.opcode] += 1

    stats.collective_by_type = dict(stats.collective_by_type)
    stats.collective_count = dict(stats.collective_count)
    stats.dot_flops_by_comp = dict(stats.dot_flops_by_comp)
    return stats
