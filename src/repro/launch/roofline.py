"""Roofline terms for TPU v5e from the compiled dry-run artifact.

Per (arch x shape x mesh) cell:
  compute term    = HLO_FLOPs_per_device / peak_FLOPs
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = wire_bytes_per_device / ICI_bw

plus MODEL_FLOPS = 6 * N_active * tokens (+ attention quadratic term)
and the useful-compute ratio MODEL_FLOPS / (HLO_FLOPs * chips).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from ..models.config import ModelConfig, ShapeCell

# TPU v5e per-chip constants (assignment-specified).
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_per_device: float
    useful_ratio: float
    bottleneck: str
    step_s: float               # max of the three terms (overlap limit)
    mfu: float                  # model_flops / (chips * peak * step_s)

    def as_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        return d


def model_flops(cfg: ModelConfig, shape: ShapeCell) -> float:
    """Useful FLOPs per step.

    train: 6 * N_active * tokens + 2x-fwd attention term
    prefill: 2 * N_active * tokens + attention term
    decode: 2 * N_active * batch (+ KV attention reads as FLOPs)
    """
    n = cfg.active_param_count()
    s, b = shape.seq_len, shape.global_batch
    if shape.kind == "train":
        base = 6.0 * n * shape.tokens
        mult = 3.0          # fwd + bwd(2x)
        tokens = shape.tokens
    elif shape.kind == "prefill":
        base = 2.0 * n * shape.tokens
        mult = 1.0
        tokens = shape.tokens
    else:
        base = 2.0 * n * b  # one token per sequence
        mult = 1.0
        tokens = b

    # Attention score/value FLOPs (quadratic or windowed/causal).
    attn = 0.0
    if cfg.has_attention:
        h, d = cfg.n_heads, cfg.head_dim
        if cfg.use_mla:
            d = cfg.qk_nope_dim + cfg.qk_rope_dim
        if shape.kind == "decode":
            kv = min(s, cfg.attn_window) if cfg.attn_window else s
            attn = 4.0 * b * h * d * kv * cfg.n_layers
        else:
            kv = min(s, cfg.attn_window) if cfg.attn_window else s
            causal = 0.5 if cfg.causal and not cfg.attn_window else 1.0
            attn = 4.0 * b * s * kv * h * d * causal * cfg.n_layers * mult
    del tokens
    return base + attn


def compute_roofline(cfg: ModelConfig, shape: ShapeCell, *,
                     n_chips: int, hlo_flops: float, hbm_bytes: float,
                     wire_bytes: float) -> Roofline:
    """All HLO inputs are per-device, trip-count-multiplied."""
    compute_s = hlo_flops / PEAK_FLOPS
    memory_s = hbm_bytes / HBM_BW
    collective_s = wire_bytes / ICI_BW
    mf = model_flops(cfg, shape)
    ratio = mf / max(hlo_flops * n_chips, 1.0)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    step = max(terms.values())
    mfu = mf / max(n_chips * PEAK_FLOPS * step, 1e-30)
    return Roofline(compute_s=compute_s, memory_s=memory_s,
                    collective_s=collective_s, model_flops=mf,
                    hlo_flops_per_device=hlo_flops, useful_ratio=ratio,
                    bottleneck=bottleneck, step_s=step, mfu=mfu)
