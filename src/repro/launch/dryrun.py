import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and record memory / cost / collective
analysis for the roofline (EXPERIMENTS.md §Dry-run, §Roofline).

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k \
      --mesh single|multi [--sync hierarchical|flat] [--out runs/dryrun]
  python -m repro.launch.dryrun --all [--mesh both]
"""
import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402

from .. import configs   # noqa: E402
from ..core import collectives  # noqa: E402
from ..models.config import SHAPES_BY_NAME, applicable_shapes, skip_reason  # noqa: E402
from . import hlo_analysis, roofline, steps  # noqa: E402
from .mesh import make_production_mesh, mesh_context  # noqa: E402

HBM_PER_CHIP = 16 * 1024 ** 3   # v5e


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             sync_mode: str = "hierarchical", out_dir: str = "runs/dryrun",
             save_hlo: bool = False) -> dict:
    cfg = configs.get(arch)
    shape = SHAPES_BY_NAME[shape_name]
    reason = skip_reason(cfg, shape)
    mesh_name = "multi" if multi_pod else "single"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "sync": sync_mode, "kind": shape.kind}
    if reason:
        rec["status"] = "skip"
        rec["skip_reason"] = reason
        return rec

    sync = (collectives.FLAT if sync_mode == "flat"
            else collectives.HIERARCHICAL)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    if shape.kind == "train":
        fn, _ = steps.build_train_step(cfg, mesh, sync=sync)
        args = steps.input_specs(cfg, shape, mesh, sync=sync)
    elif shape.kind == "prefill":
        fn, _ = steps.build_prefill_step(cfg, mesh,
                                         batch=shape.global_batch,
                                         seq_len=shape.seq_len)
        args = steps.input_specs(cfg, shape, mesh)
    else:
        fn, _ = steps.build_decode_step(cfg, mesh,
                                        batch=shape.global_batch,
                                        max_len=shape.seq_len)
        args = steps.input_specs(cfg, shape, mesh)

    with mesh_context(mesh):
        lowered = fn.lower(*args)
    rec["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
    }
    resident = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    rec["memory"]["resident_bytes"] = int(resident)
    rec["memory"]["fits_16gib"] = bool(resident <= HBM_PER_CHIP)

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # jax < 0.5: one dict per program
        cost = cost[0] if cost else {}
    rec["xla_cost"] = {k: float(v) for k, v in cost.items()
                      if k in ("flops", "bytes accessed")}

    hlo = compiled.as_text()
    rec["hlo_chars"] = len(hlo)
    stats = hlo_analysis.analyze(hlo)
    rec["hlo"] = {
        "flops": stats.flops,
        "hbm_bytes": stats.hbm_bytes,
        "collective_bytes": stats.collective_bytes,
        "collective_by_type": stats.collective_by_type,
        "collective_count": stats.collective_count,
    }
    rl = roofline.compute_roofline(
        cfg, shape, n_chips=n_chips, hlo_flops=stats.flops,
        hbm_bytes=stats.hbm_bytes, wire_bytes=stats.collective_bytes)
    rec["roofline"] = rl.as_dict()
    rec["status"] = "ok"

    if save_hlo:
        Path(out_dir).mkdir(parents=True, exist_ok=True)
        hpath = Path(out_dir) / f"{arch}_{shape_name}_{mesh_name}.hlo"
        hpath.write_text(hlo)
        rec["hlo_path"] = str(hpath)
    return rec


def save(rec: dict, out_dir: str):
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    name = (f"{rec['arch']}_{rec['shape']}_{rec['mesh']}"
            f"_{rec.get('sync', 'hierarchical')}.json")
    (out / name).write_text(json.dumps(rec, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--sync", default="hierarchical",
                    choices=["hierarchical", "flat"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    meshes = (["single", "multi"] if args.mesh == "both"
              else [args.mesh])
    cells = []
    if args.all:
        for arch in configs.ARCH_IDS:
            cfg = configs.get(arch)
            for shape in SHAPES_BY_NAME.values():
                cells.append((arch, shape.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(configs.ALIASES.get(args.arch, args.arch), args.shape)]

    failures = 0
    for arch, shape in cells:
        for mesh_name in meshes:
            key = f"{arch} x {shape} x {mesh_name}"
            try:
                rec = run_cell(arch, shape, mesh_name == "multi",
                               args.sync, args.out,
                               save_hlo=args.save_hlo)
            except Exception as e:  # noqa: BLE001
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "sync": args.sync, "status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                failures += 1
            save(rec, args.out)
            status = rec["status"]
            extra = ""
            if status == "ok":
                r = rec["roofline"]
                extra = (f" bottleneck={r['bottleneck']}"
                         f" mfu={r['mfu']:.3f}"
                         f" resident={rec['memory']['resident_bytes']/2**30:.1f}GiB"
                         f" fits={rec['memory']['fits_16gib']}"
                         f" compile={rec['compile_s']}s")
            elif status == "skip":
                extra = f" ({rec['skip_reason']})"
            else:
                extra = f" {rec['error'][:120]}"
            print(f"[dryrun] {key}: {status}{extra}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
