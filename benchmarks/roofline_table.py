"""Roofline table: reads the dry-run JSON records (runs/dryrun) and
emits the per-cell terms (EXPERIMENTS.md §Roofline)."""
import json
from pathlib import Path

DRYRUN_DIR = Path(__file__).resolve().parent.parent / "runs" / "dryrun"


def load_records(mesh="single"):
    recs = []
    if not DRYRUN_DIR.exists():
        return recs
    for f in sorted(DRYRUN_DIR.glob(f"*_{mesh}_*.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") == "ok":
            recs.append(rec)
    return recs


def run():
    rows = []
    for rec in load_records():
        r = rec["roofline"]
        tag = f"roofline_{rec['arch']}_{rec['shape']}"
        rows.append((f"{tag}_bottleneck", 0.0, r["bottleneck"]))
        rows.append((f"{tag}_mfu", 0.0, round(r["mfu"], 4)))
        rows.append((f"{tag}_compute_s", 0.0, round(r["compute_s"], 3)))
        rows.append((f"{tag}_memory_s", 0.0, round(r["memory_s"], 3)))
        rows.append((f"{tag}_collective_s", 0.0,
                     round(r["collective_s"], 3)))
        rows.append((f"{tag}_useful_ratio", 0.0,
                     round(r["useful_ratio"], 3)))
    if not rows:
        rows.append(("roofline_no_dryrun_records", 0.0,
                     "run python -m repro.launch.dryrun --all first"))
    return rows
