"""Fig. 4a/4b: barrier cycles vs radix vs arrival scatter, and the
synchronization-free region needed for <10% overhead.

The whole radix x delay x trial grid runs through ONE jitted, vmapped
call of the sweep engine (:mod:`repro.core.sweep`); fig4b reuses the
fig4a sweep results instead of re-simulating per (delay, radix) point.
"""
import numpy as np

import jax

from repro.core import barrier, sweep

from . import timing

KEY = jax.random.PRNGKey(0)
DELAYS = [0.0, 128.0, 512.0, 2048.0]
SFRS = [500, 1000, 2000, 5000, 10000, 20000]
N_TRIALS = 16


def run_sweep():
    """One compiled call for the full grid, timed compile vs steady."""
    radices = list(barrier.all_radices())
    res, steady_us, compile_us = timing.measure(
        lambda: sweep.sweep_barrier(KEY, radices=radices, delays=DELAYS,
                                    n_trials=N_TRIALS))
    return res, steady_us, compile_us


def fig4a(res, steady_us, compile_us):
    rows = [("fig4a_sweep_grid", steady_us,
             f"{res.mean_span.shape[0]}x{res.mean_span.shape[1]}"
             f"x{N_TRIALS}", compile_us)]
    spans = np.asarray(res.mean_span)
    for i, radix in enumerate(np.asarray(res.radices)):
        for j, delay in enumerate(np.asarray(res.delays)):
            rows.append((f"fig4a_radix{radix}_delay{int(delay)}", 0.0,
                         round(float(spans[i, j]), 1), 0.0))
    return rows


def fig4b(res):
    """Overhead vs SFR at the best radix per delay — computed from the
    fig4a sweep results, no re-simulation."""
    rows = []
    radices = np.asarray(res.radices)
    spans = np.asarray(res.mean_span)            # (R, D)
    resid = np.asarray(res.mean_residency_grid)  # (R, D)
    for j, delay in enumerate(np.asarray(res.delays)):
        i = int(np.argmin(spans[:, j]))
        radix = int(radices[i])
        barrier_cost = float(resid[i, j])
        for sfr in SFRS:
            frac = barrier_cost / (sfr + barrier_cost)
            rows.append((f"fig4b_delay{int(delay)}_sfr{sfr}_radix{radix}",
                         0.0, round(frac, 4), 0.0))
    return rows


def run():
    res, steady_us, compile_us = run_sweep()
    return fig4a(res, steady_us, compile_us) + fig4b(res)
