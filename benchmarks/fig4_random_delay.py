"""Fig. 4a/4b: barrier cycles vs radix vs arrival scatter, and the
synchronization-free region needed for <10% overhead."""
import time

import jax
import jax.numpy as jnp

from repro.core import barrier, barrier_sim

KEY = jax.random.PRNGKey(0)
DELAYS = [0.0, 128.0, 512.0, 2048.0]
SFRS = [500, 1000, 2000, 5000, 10000, 20000]


def fig4a():
    rows = []
    for radix in barrier.all_radices():
        sched = barrier.kary_tree(radix)
        for delay in DELAYS:
            t0 = time.perf_counter()
            span = float(barrier_sim.mean_span_cycles(KEY, sched, delay,
                                                      n_trials=16))
            us = (time.perf_counter() - t0) * 1e6
            rows.append((f"fig4a_radix{radix}_delay{int(delay)}", us,
                         round(span, 1)))
    return rows


def fig4b():
    rows = []
    for delay in DELAYS:
        # best radix per scatter level
        best = min(
            ((float(barrier_sim.mean_span_cycles(KEY,
                                                 barrier.kary_tree(r),
                                                 delay, n_trials=8)), r)
             for r in (2, 16, 32, 64, 256, 1024)))
        radix = best[1]
        sched = barrier.kary_tree(radix)
        for sfr in SFRS:
            t0 = time.perf_counter()
            frac = float(barrier_sim.overhead_fraction(
                KEY, sched, sfr, delay, n_trials=8))
            us = (time.perf_counter() - t0) * 1e6
            rows.append((f"fig4b_delay{int(delay)}_sfr{sfr}_radix{radix}",
                         us, round(frac, 4)))
    return rows


def run():
    return fig4a() + fig4b()
