"""Core-performance benchmark: the simulator cores head to head.

Times the repo's three hot grids — the Fig. 4 uniform-radix sweep
(``sweep_barrier``), the exhaustive mixed-radix tuner grid
(``tune_barrier``: 512 compositions at N=1024), and the workload-
conditioned arrival sweep (``sweep_arrivals`` via
``tuning.sweep_workloads``) — under BOTH simulator cores (the
full-width ``scan`` oracle and the shrinking-width ``telescope``
production core) at N in {256, 1024}.

Reports steady-state µs per grid POINT (one point = one simulated
barrier episode) with compile time split out, and writes the whole
record to ``BENCH_core.json`` at the repo root so the perf trajectory
of the hottest path is tracked across PRs.

Environment knobs (CI smoke uses both):
  * ``REPRO_BENCH_CORE_NS``   — comma-separated cluster sizes
    (default ``256,1024``).
  * ``BENCH_CORE_JSON``       — output path (default
    ``<repo>/BENCH_core.json``).
"""
import json
import os
from pathlib import Path

import jax

from repro.core import sweep, tuning

from . import timing

KEY = jax.random.PRNGKey(0)
DELAYS = (0.0, 128.0, 512.0, 2048.0)
CORES = ("scan", "telescope")
KERNELS = ("dotp_1Mi", "conv2d_256x256", "matmul_256x128x256")

_NS = tuple(int(x) for x in os.environ.get(
    "REPRO_BENCH_CORE_NS", "256,1024").split(","))
_OUT = Path(os.environ.get(
    "BENCH_CORE_JSON",
    Path(__file__).resolve().parent.parent / "BENCH_core.json"))


def _grids(n):
    """(grid_name, n_points, fn(core)) for the three hot consumers."""
    n_sched = len(tuning.enumerate_compositions(n))
    n_radices = n.bit_length() - 1
    yield ("sweep_barrier", n_radices * len(DELAYS) * 16,
           lambda core: sweep.sweep_barrier(
               KEY, n_pes=n, delays=DELAYS, n_trials=16, core=core))
    yield ("tune_barrier", n_sched * len(DELAYS) * 4,
           lambda core: tuning.tune_barrier(
               KEY, n, delays=DELAYS, n_trials=4, core=core))
    yield ("sweep_arrivals", n_sched * len(KERNELS) * 4,
           lambda core: tuning.sweep_workloads(
               KEY, KERNELS, n, n_trials=4, core=core))


def run():
    rows = []
    record = {}
    for n in _NS:
        record[f"N={n}"] = {}
        for gname, n_points, fn in _grids(n):
            entry = {"points": n_points}
            for core in CORES:
                _, steady_us, compile_us = timing.measure(
                    lambda: fn(core).span_cycles, iters=2)
                per_point = steady_us / n_points
                entry[core] = {
                    "steady_us": round(steady_us, 1),
                    "compile_us": round(compile_us, 1),
                    "us_per_point": round(per_point, 3),
                }
                rows.append((f"core_{gname}_N{n}_{core}", per_point,
                             f"{n_points}pts", compile_us))
            entry["speedup"] = round(
                entry["scan"]["us_per_point"]
                / entry["telescope"]["us_per_point"], 2)
            record[f"N={n}"][gname] = entry
            rows.append((f"core_{gname}_N{n}_speedup", 0.0,
                         entry["speedup"], 0.0))
    _OUT.write_text(json.dumps(record, indent=2) + "\n")
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
