"""Tuned mixed-radix trees vs the best uniform radix (the paper's
Sec. 5 fine-tuning step, generalized): the exhaustive composition x
delay x trial sweep runs through ONE compiled program, and the winning
composition at each arrival scatter is reported against the best
uniform-radix tree on the Fig. 4a mean-span metric.  A second block
reports the 5G application under the tuned sync modes (tuned partial
stage trees + tuned global tree) next to the paper's fixed-radix
strategies.
"""
import jax

from repro.core import fiveg, tuning

from . import timing

KEY = jax.random.PRNGKey(0)
DELAYS = [0.0, 128.0, 512.0, 2048.0]
N_TRIALS = 4   # the composition axis (512 at N=1024) dominates runtime


def tuned_vs_uniform():
    res, steady_us, compile_us = timing.measure(
        lambda: tuning.tune_barrier(KEY, delays=DELAYS, n_trials=N_TRIALS),
        warmup=0, iters=1)
    n_sched = len(res.schedules)
    rows = [("tuned_sweep_grid", steady_us,
             f"{n_sched}x{len(DELAYS)}x{N_TRIALS}", compile_us)]
    for p in tuning.best_per_delay(res):
        d = int(p.delay)
        rows.append((f"tuned_delay{d}_best_{p.schedule.name}", 0.0,
                     round(p.mean_span, 1), 0.0))
        rows.append((f"tuned_delay{d}_uniform_{p.uniform_schedule.name}",
                     0.0, round(p.uniform_span, 1), 0.0))
        rows.append((f"tuned_delay{d}_gain", 0.0,
                     round(p.uniform_span / p.mean_span, 4), 0.0))
    rows.append(("tuned_pareto_front", 0.0,
                 "|".join(s.name for s in tuning.pareto_schedules(res)),
                 0.0))
    return rows


def tuned_5g():
    app = fiveg.FiveGConfig(n_rx=16, ffts_per_round=1)
    res, steady_us, compile_us = timing.measure(
        lambda: fiveg.compare_barriers(
            KEY, app, radix=32,
            modes=("central", "partial", "tuned", "tuned_partial")),
        warmup=0, iters=1)
    rows = [("tuned_5g_compare", steady_us, "4modes", compile_us)]
    for mode in ("partial", "tuned", "tuned_partial"):
        rows.append((f"tuned_5g_speedup_{mode}", 0.0,
                     round(float(res[f"speedup_{mode}"]), 3), 0.0))
        rows.append((f"tuned_5g_syncfrac_{mode}", 0.0,
                     round(float(res[mode].sync_fraction), 4), 0.0))
    return rows


def run():
    return tuned_vs_uniform() + tuned_5g()
