"""Fig. 5: CDF of fastest-vs-slowest PE runtime per kernel/input."""
import jax
import jax.numpy as jnp

from repro.core import workloads

from . import timing

KEY = jax.random.PRNGKey(1)


def run():
    rows = []
    suite = workloads.benchmark_suite()
    for kernel, dims in suite.items():
        for label, fn in dims.items():
            arr, steady_us, compile_us = timing.measure(lambda: fn(KEY))
            gap = float(workloads.cdf_first_last_gap(arr))
            p50 = float(jnp.percentile(arr - jnp.min(arr), 50))
            rows.append((f"fig5_{kernel}_{label}_gap", steady_us,
                         round(gap, 1), compile_us))
            rows.append((f"fig5_{kernel}_{label}_p50", steady_us,
                         round(p50, 1), compile_us))
    return rows
