"""Fig. 5: CDF of fastest-vs-slowest PE runtime per kernel/input."""
import time

import jax
import jax.numpy as jnp

from repro.core import workloads

KEY = jax.random.PRNGKey(1)


def run():
    rows = []
    suite = workloads.benchmark_suite()
    for kernel, dims in suite.items():
        for label, fn in dims.items():
            t0 = time.perf_counter()
            arr = fn(KEY)
            gap = float(workloads.cdf_first_last_gap(arr))
            p50 = float(jnp.percentile(arr - jnp.min(arr), 50))
            us = (time.perf_counter() - t0) * 1e6
            rows.append((f"fig5_{kernel}_{label}_gap", us, round(gap, 1)))
            rows.append((f"fig5_{kernel}_{label}_p50", us, round(p50, 1)))
    return rows
