"""Fault-degradation benchmark: barrier tail latency vs PE failures.

At thousand-PE scale persistent PE loss is an operating condition, not
an exception.  This benchmark measures how the tuned barrier design
space degrades when a growing fraction of PEs fail-stop (arrive at
``+inf``) under a watchdog-timeout release policy, and whether tuning
for the TAIL (p99 span) under faults picks a different — and better —
schedule than the classic fault-free latency tuner.

Two measurements, written to ``BENCH_faults.json`` at the repo root:

* **Degradation curve over the schedule stack** — the fail-rate axis
  rides the kernel axis of ONE :func:`repro.core.sweep.sweep_arrivals`
  call: the same base arrival draws are masked at each rate, stacked
  to ``(R, T, N)``, and swept across the hierarchy-pruned composition
  stack through the single compiled robust core.  Per rate we report
  the fault-free latency winner (argmin mean span at rate 0) and the
  robustness winner (argmin p99 span at that rate), both evaluated on
  the SAME faulted arrivals — the headline is the p99 gap between
  them once >= 1% of PEs are dead.
* **5G pipeline under PE loss** — :func:`repro.core.fiveg.
  degradation_curve`: end-to-end OFDM+beamforming throughput and
  completion rate vs fail rate for the central counter, the radix-32
  tree and the hw event unit, all rates of one mode through one
  compiled robust pipeline.

Environment knobs (CI smoke uses ``--smoke``):
  * ``REPRO_BENCH_FAULTS_N``      — cluster size (default 1024;
    ``--smoke`` defaults to 64).
  * ``REPRO_BENCH_FAULTS_RATES``  — comma-separated PE fail rates
    (default ``0.0,0.005,0.01,0.02,0.05``).
  * ``REPRO_BENCH_FAULTS_TRIALS`` — trials per rate (default 64;
    smoke 8).
  * ``REPRO_BENCH_FAULTS_TIMEOUT``— watchdog cycles (default 2000).
  * ``REPRO_BENCH_FAULTS_QUORUM`` — quorum fraction (default 0.95).
  * ``BENCH_FAULTS_JSON``         — output path (default
    ``<repo>/BENCH_faults.json``).
"""
import json
import os
import sys
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core import barrier, fiveg, sweep, tuning
from repro.core.barrier import fault_spec
from repro.core.topology import DEFAULT, TeraPoolConfig

from . import timing

SMOKE = "--smoke" in sys.argv

KEY = jax.random.PRNGKey(0)
DELAY = 512.0   # base arrival scatter (cycles), the Fig. 4 mid-regime

_N = int(os.environ.get("REPRO_BENCH_FAULTS_N",
                        "64" if SMOKE else "1024"))
_RATES = tuple(float(x) for x in os.environ.get(
    "REPRO_BENCH_FAULTS_RATES", "0.0,0.005,0.01,0.02,0.05").split(","))
_TRIALS = int(os.environ.get("REPRO_BENCH_FAULTS_TRIALS",
                             "8" if SMOKE else "64"))
_TIMEOUT = float(os.environ.get("REPRO_BENCH_FAULTS_TIMEOUT", "2000"))
_QUORUM = float(os.environ.get("REPRO_BENCH_FAULTS_QUORUM", "0.95"))
_OUT = Path(os.environ.get(
    "BENCH_FAULTS_JSON",
    Path(__file__).resolve().parent.parent / "BENCH_faults.json"))


def _cfg(n: int) -> TeraPoolConfig:
    return DEFAULT if n == DEFAULT.n_pes else TeraPoolConfig(n_pes=n)


def _p99(span_cycles: jnp.ndarray) -> jnp.ndarray:
    """(S, R) p99 span; 'lower' keeps it finite under <1% hung trials."""
    return jnp.percentile(span_cycles, 99.0, axis=-1, method="lower")


def _faulted_stack(key, n: int) -> jnp.ndarray:
    """(R, T, N) arrivals: ONE base draw, fail-stop masked per rate.

    Sharing the base draw across rates isolates the fault axis — the
    rate-0 slice is exactly the clean workload the latency tuner sees.
    """
    k_arr, k_mask = jax.random.split(key)
    base = jax.random.uniform(k_arr, (_TRIALS, n), jnp.float32,
                              0.0, DELAY)
    stacks = []
    for i, rate in enumerate(_RATES):
        mask = jax.random.bernoulli(jax.random.fold_in(k_mask, i),
                                    rate, (_TRIALS, n))
        stacks.append(jnp.where(mask, jnp.inf, base))
    return jnp.stack(stacks)


def _schedule_point(res, i: int, j: int, p99, spans) -> dict:
    placs = res.placements or (None,) * len(res.schedules)
    return {
        "schedule": barrier.schedule_name(res.schedules[i], placs[i]),
        "p99_cycles": round(float(p99[i, j]), 1),
        "mean_cycles": round(float(spans[i, j]), 1),
        "completion_rate": round(float(res.completion_rate[i, j]), 5),
        "abandoned_pes_mean": round(
            float(jnp.mean(res.abandoned_pes[i, j].astype(jnp.float32))),
            2),
    }


def _stack(cfg) -> list:
    """Hierarchy-matched deep trees (the latency tuner's home turf)
    PLUS the wide shallow baselines (radix-32 tree, central counter)
    that pay fewer per-level watchdog deadlines when PEs die."""
    scheds = list(tuning.all_schedules(cfg.n_pes, cfg, prune="hierarchy"))
    names = {barrier.schedule_name(s, None) for s in scheds}
    for extra in (barrier.kary_tree(min(32, cfg.n_pes), cfg=cfg),
                  barrier.central_counter(cfg=cfg)):
        if barrier.schedule_name(extra, None) not in names:
            scheds.append(extra)
    return scheds


def _degradation_sweep(rows: list) -> dict:
    cfg = _cfg(_N)
    scheds = _stack(cfg)
    spec = fault_spec(timeout_cycles=_TIMEOUT, quorum_frac=_QUORUM)
    arrivals = _faulted_stack(KEY, _N)
    labels = tuple(f"fail_{r:g}" for r in _RATES)
    res, steady_us, compile_us = timing.measure(
        lambda: sweep.sweep_arrivals(arrivals, scheds, cfg,
                                     kernels=labels, faults=spec,
                                     trial_chunk=min(16, _TRIALS)),
        iters=1)
    spans = jnp.mean(res.span_cycles, axis=-1)          # (S, R)
    p99 = _p99(res.span_cycles)                         # (S, R)

    # The classic tuner's pick: argmin MEAN span on the CLEAN arrivals
    # under the PLAIN (fault-oblivious) simulator — the schedule you
    # would deploy if you tuned without thinking about failures.
    clean = sweep.sweep_arrivals(arrivals[:1], scheds, cfg,
                                 kernels=labels[:1],
                                 trial_chunk=min(16, _TRIALS))
    i_lat = int(jnp.argmin(jnp.mean(clean.span_cycles, axis=-1)[:, 0]))
    curve = []
    for j, rate in enumerate(_RATES):
        i_rob = int(jnp.argmin(p99[:, j]))
        lat = _schedule_point(res, i_lat, j, p99, spans)
        rob = _schedule_point(res, i_rob, j, p99, spans)
        curve.append({
            "fail_rate": rate,
            "latency_tuned": lat,
            "robust_tuned": rob,
            "p99_improvement": round(
                lat["p99_cycles"] / max(rob["p99_cycles"], 1e-9), 4),
        })
        rows.append((f"faults_rate{rate:g}_N{_N}",
                     steady_us / len(_RATES),
                     f"p99 {lat['p99_cycles']}->{rob['p99_cycles']}",
                     compile_us / len(_RATES)))
    beats = [c["p99_improvement"] > 1.0
             for c in curve if c["fail_rate"] >= 0.01]
    return {
        "n_pes": _N,
        "n_schedules": len(scheds),
        "n_trials": _TRIALS,
        "base_delay": DELAY,
        "timeout_cycles": _TIMEOUT,
        "quorum_frac": _QUORUM,
        "curve": curve,
        "robust_beats_latency_at_1pct": bool(beats and all(beats)),
    }


def _fiveg_degradation(rows: list) -> dict:
    cfg = _cfg(_N)
    # The app config only unrolls real FFT epochs on the full machine
    # (concurrent_ffts is derived from the 1024-PE cluster); smaller
    # smoke clusters exercise the two global barriers only.
    app = fiveg.FiveGConfig(n_rx=16, ffts_per_round=1)
    rates = _RATES if not SMOKE else _RATES[:2]
    out, steady_us, compile_us = timing.measure(
        lambda: fiveg.degradation_curve(
            KEY, rates, app, cfg=cfg, core="scan",
            timeout_cycles=_TIMEOUT, quorum_frac=_QUORUM),
        iters=1)
    entry = {"n_pes": _N, "fail_rates": list(rates)}
    for mode in ("central", "tree", "hw"):
        entry[mode] = [{
            "fail_rate": r,
            "total_cycles": round(float(res.total_cycles), 1),
            "completion_rate": round(float(res.completion_rate), 5),
            "timed_out_levels": round(float(res.timed_out_levels), 1),
        } for r, res in zip(rates, out[mode])]
    rows.append((f"faults_5g_N{_N}", steady_us,
                 f"{len(rates)}rates x 3modes", compile_us))
    return entry


def run():
    rows = []
    record = {"degradation": _degradation_sweep(rows),
              "fiveg": _fiveg_degradation(rows)}
    _OUT.write_text(json.dumps(record, indent=2) + "\n")
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
