"""Resilience benchmark: what durability costs, and how fast a killed
sweep comes back.

Two questions, measured on the tuner grid (hierarchy-pruned
compositions at N>256 so the acceptance run at N=1024 matches how the
tuner is actually driven at that scale):

* **Checkpoint overhead** — steady-state wall time of the resilient
  chunk loop (:func:`repro.runtime.resilient_sweep_schedules`, fresh
  store every call, so every chunk is computed AND checkpointed) vs the
  plain chunked engine (:func:`repro.core.sweep.sweep_schedules` at the
  same ``trial_chunk``), across chunk sizes including the default.  The
  acceptance bar is <= 10% at N=1024 at ``DEFAULT_TRIAL_CHUNK``.
* **Recovery latency** — a run killed by an injected
  :class:`~repro.runtime.inject.Preemption` mid-grid, then resumed:
  the resumed call's wall time and how many chunks it restored vs
  recomputed.

Environment knobs (CI smoke shrinks the cluster):
  * ``REPRO_BENCH_RESILIENCE_N`` — cluster size (default ``1024``).
"""
import os
import shutil
import tempfile
import time
from pathlib import Path

import jax

from repro.core import sweep, tuning
from repro.runtime import (FaultPlan, Preemption, ResilienceConfig,
                           SimulatedFault, resilient_sweep_schedules)
from repro.runtime.resilient_sweep import DEFAULT_TRIAL_CHUNK

from . import timing

KEY = jax.random.PRNGKey(0)
DELAYS = (0.0, 512.0)
N = int(os.environ.get("REPRO_BENCH_RESILIENCE_N", "1024"))
N_TRIALS = 16
CHUNKS = tuple(sorted({4, DEFAULT_TRIAL_CHUNK, 16}))


def run():
    rows = []
    prune = "hierarchy" if N > 256 else "none"
    scheds = tuning.all_schedules(N, prune=prune)
    root = Path(tempfile.mkdtemp(prefix="bench_resilience_"))
    try:
        for chunk in CHUNKS:
            _, plain_us, plain_compile = timing.measure(
                lambda: sweep.sweep_schedules(
                    KEY, scheds, DELAYS, N_TRIALS,
                    trial_chunk=chunk).span_cycles,
                warmup=0, iters=2)

            def resilient():
                # wipe the store: every timed call computes (and
                # checkpoints) every chunk, never resumes
                d = root / f"chunk{chunk}"
                shutil.rmtree(d, ignore_errors=True)
                rc = ResilienceConfig(ckpt_dir=str(d), trial_chunk=chunk)
                return resilient_sweep_schedules(
                    KEY, scheds, DELAYS, N_TRIALS,
                    resilience=rc).result.span_cycles

            _, res_us, res_compile = timing.measure(
                resilient, warmup=0, iters=2)
            overhead = 100.0 * (res_us - plain_us) / plain_us
            rows.append((f"resilience_plain_N{N}_c{chunk}", plain_us,
                         f"{len(scheds)}sched", plain_compile))
            rows.append((f"resilience_ckpt_N{N}_c{chunk}", res_us,
                         f"overhead={overhead:.1f}%", res_compile))

        # Recovery latency: kill mid-grid, then time the resumed call.
        chunk = DEFAULT_TRIAL_CHUNK
        n_chunks = -(-N_TRIALS // chunk)
        kill_at = n_chunks // 2
        d = root / "recovery"
        rc = ResilienceConfig(ckpt_dir=str(d), trial_chunk=chunk)
        plan = FaultPlan(faults={kill_at: Preemption()})
        t0 = time.perf_counter()
        try:
            resilient_sweep_schedules(KEY, scheds, DELAYS, N_TRIALS,
                                      resilience=rc, fault_plan=plan)
        except SimulatedFault:
            pass
        kill_us = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        rep = resilient_sweep_schedules(KEY, scheds, DELAYS, N_TRIALS,
                                        resilience=rc, fault_plan=plan)
        resume_us = (time.perf_counter() - t0) * 1e6
        rows.append((f"resilience_killed_N{N}", kill_us,
                     f"killed@chunk{kill_at}", 0.0))
        rows.append((f"resilience_recovery_N{N}", resume_us,
                     f"resumed{rep.chunks_resumed}/{rep.chunks_total}",
                     0.0))
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
