"""Fig. 6a/6b/6c: per (kernel x input x radix): barrier delay, barrier
fraction of total runtime, and the fastest-vs-slowest-barrier speedup."""
import time

import jax
import jax.numpy as jnp

from repro.core import barrier, barrier_sim, workloads

KEY = jax.random.PRNGKey(2)
RADICES = [2, 8, 16, 32, 64, 256, 1024]


def run():
    rows = []
    suite = workloads.benchmark_suite()
    for kernel, dims in suite.items():
        for label, fn in dims.items():
            arr = fn(KEY)
            totals, fracs = {}, {}
            for radix in RADICES:
                sched = barrier.kary_tree(radix)
                res = barrier_sim.simulate(arr, sched)
                totals[radix] = float(res.exit_time)
                fracs[radix] = float(res.mean_residency
                                     / res.exit_time)
            best = min(totals, key=totals.get)
            worst = max(totals, key=totals.get)
            speedup = totals[worst] / totals[best]
            rows.append((f"fig6a_{kernel}_{label}_bestradix", 0.0, best))
            rows.append((f"fig6b_{kernel}_{label}_frac", 0.0,
                         round(fracs[best], 4)))
            rows.append((f"fig6c_{kernel}_{label}_speedup", 0.0,
                         round(speedup, 3)))
    return rows
