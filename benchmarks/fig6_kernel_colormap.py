"""Fig. 6a/6b/6c: per (kernel x input x radix): barrier delay, barrier
fraction of total runtime, and the fastest-vs-slowest-barrier speedup.

Each kernel's arrival vector is swept across the whole radix stack in
one vmapped call (:func:`repro.core.sweep.simulate_radices`); the stack
shares one compile across kernels and inputs.
"""
import numpy as np

import jax

from repro.core import sweep, workloads

from . import timing

KEY = jax.random.PRNGKey(2)
RADICES = [2, 8, 16, 32, 64, 256, 1024]


def run():
    rows = []
    suite = workloads.benchmark_suite()
    for kernel, dims in suite.items():
        for label, fn in dims.items():
            arr = fn(KEY)
            res, steady_us, compile_us = timing.measure(
                lambda: sweep.simulate_radices(arr, RADICES))
            totals = np.asarray(res.exit_time)
            fracs = np.asarray(res.mean_residency) / totals
            best_i = int(np.argmin(totals))
            speedup = float(np.max(totals) / totals[best_i])
            rows.append((f"fig6a_{kernel}_{label}_bestradix", steady_us,
                         RADICES[best_i], compile_us))
            rows.append((f"fig6b_{kernel}_{label}_frac", steady_us,
                         round(float(fracs[best_i]), 4), compile_us))
            rows.append((f"fig6c_{kernel}_{label}_speedup", steady_us,
                         round(speedup, 3), compile_us))
    return rows
