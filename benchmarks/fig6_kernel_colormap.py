"""Fig. 6a/6b/6c: per (kernel x input x radix): barrier delay, barrier
fraction of total runtime, and the fastest-vs-slowest-barrier speedup.

The whole kernel x input x radix grid runs through ONE vmapped call of
the data-dependent sweep engine (:func:`repro.core.sweep.
sweep_arrivals`): every kernel's arrival vector is stacked along the
workload axis and dispatched once — the seed path re-dispatched
``simulate_radices`` per kernel/input, paying 15 dispatches (and
masking compile in the per-row timing).  Compile and steady-state time
are reported as separate columns on the single grid row, like fig4;
the per-kernel rows derive from that one call at 0.0 cost.
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import barrier, sweep, workloads

from . import timing

KEY = jax.random.PRNGKey(2)
RADICES = [2, 8, 16, 32, 64, 256, 1024]


def run():
    suite = workloads.benchmark_suite()
    labels = [(kernel, label) for kernel, dims in suite.items()
              for label in dims]
    # Same single draw per kernel/input as the seed path (shared KEY).
    arrivals = jnp.stack([suite[k][l](KEY) for k, l in labels])[:, None, :]
    scheds = [barrier.kary_tree(r) for r in RADICES]
    res, steady_us, compile_us = timing.measure(
        lambda: sweep.sweep_arrivals(
            arrivals, scheds, kernels=[f"{k}_{l}" for k, l in labels]))
    rows = [("fig6_sweep_grid", steady_us,
             f"{len(RADICES)}x{len(labels)}x1", compile_us)]
    totals = np.asarray(res.exit_time)[:, :, 0]          # (R, K)
    fracs = np.asarray(res.mean_residency)[:, :, 0] / totals
    for j, (kernel, label) in enumerate(labels):
        best_i = int(np.argmin(totals[:, j]))
        speedup = float(np.max(totals[:, j]) / totals[best_i, j])
        rows.append((f"fig6a_{kernel}_{label}_bestradix", 0.0,
                     RADICES[best_i], 0.0))
        rows.append((f"fig6b_{kernel}_{label}_frac", 0.0,
                     round(float(fracs[best_i, j]), 4), 0.0))
        rows.append((f"fig6c_{kernel}_{label}_speedup", 0.0,
                     round(speedup, 3), 0.0))
    return rows
