"""Workload-conditioned tuning (beyond-figure): the hierarchy-pruned
composition space swept over every Fig. 5/6 kernel's MEASURED arrival
batch — kernel x schedule x trial through ONE compiled scanned core —
and the per-kernel tuned schedule reported against the best uniform
radix on the same arrivals (the radix that wins ``dotp``'s
atomic-reduction tail loses ``conv2d``'s bimodal border imbalance).  A
second block runs the 5G app under ``sync="workload"`` (stage and
FFT->MATMUL barriers tuned separately on their own epoch models) next
to the uniform-proxy-tuned ``placed`` mode, printing the winning
per-epoch schedules now exposed by ``FiveGResult``.
"""
import jax

from repro.core import barrier, fiveg, tuning

from . import timing

KEY = jax.random.PRNGKey(4)
N_TRIALS = 4


def workload_tuned_kernels():
    # Hierarchy-pruned compositions + EVERY uniform radix, so the
    # reported baseline is the true best uniform tree (most uniform
    # radices straddle a Tile/Group boundary and are pruned away).
    scheds = tuning.all_schedules(prune="hierarchy")
    scheds += [s for r in barrier.all_radices()
               if (s := barrier.kary_tree(r)) not in scheds]
    res, steady_us, compile_us = timing.measure(
        lambda: tuning.sweep_workloads(KEY, n_trials=N_TRIALS,
                                       schedules=scheds),
        warmup=0, iters=1)
    rows = [("workload_sweep_grid", steady_us,
             f"{len(res.schedules)}x{len(res.kernels)}x{N_TRIALS}",
             compile_us)]
    for p in tuning.best_per_kernel(res):
        rows.append((f"workload_{p.kernel}_best_{p.schedule.name}", 0.0,
                     round(p.mean_span, 1), 0.0))
        rows.append((f"workload_{p.kernel}_uniform_"
                     f"{p.uniform_schedule.name}", 0.0,
                     round(p.uniform_span, 1), 0.0))
        rows.append((f"workload_{p.kernel}_gain", 0.0,
                     round(p.uniform_span / max(p.mean_span, 1e-9), 4),
                     0.0))
    return rows


def workload_5g():
    app = fiveg.FiveGConfig()   # the paper's 4x16-FFT design point
    res, steady_us, compile_us = timing.measure(
        lambda: fiveg.compare_barriers(
            KEY, app, radix=32,
            modes=("central", "partial", "placed", "workload")),
        warmup=0, iters=1)
    rows = [("workload_5g_compare", steady_us, "4modes", compile_us)]
    for mode in ("partial", "placed", "workload"):
        rows.append((f"workload_5g_speedup_{mode}", 0.0,
                     round(float(res[f"speedup_{mode}"]), 3), 0.0))
        rows.append((f"workload_5g_syncfrac_{mode}", 0.0,
                     round(float(res[mode].sync_fraction), 4), 0.0))
    for mode in ("placed", "workload"):
        rows.append((f"workload_5g_{mode}_stage_sched", 0.0,
                     res[mode].stage_schedule, 0.0))
        rows.append((f"workload_5g_{mode}_global_sched", 0.0,
                     res[mode].global_schedule, 0.0))
    return rows


def run():
    return workload_tuned_kernels() + workload_5g()
