"""Energy benchmark: hardware-vs-software barriers on the joint
latency x energy plane (Glaser et al., arXiv 2004.06662, on TeraPool).

Three measurements, written to ``BENCH_energy.json`` at the repo root:

* **Energy per barrier vs N** — mean episode energy (pJ) and span
  (cycles) of the central counter, the radix-32 tree and the hardware
  event unit at each machine size, on the same simultaneous-arrival
  draws.  The hardware primitive should dominate every software tree
  on BOTH axes (the Glaser headline).
* **Pareto front at the largest N** — the 2-D latency x energy front
  over the EXHAUSTIVE software composition space at delay 0
  (:func:`repro.core.tuning.pareto_front`): deep hierarchy-matched
  trees win cycles, wide shallow trees win energy (fewer counter
  RMWs), and the staircase between them is the tuner's offer to a
  latency/energy budget.  The hw point is appended separately — it
  dominates the entire software front, which is the point.
* **5G energy overhead** — ``fiveg.compare_barriers`` with
  ``sync="hw"``: the fraction of application energy spent inside
  barriers per mode, plus hw-vs-central sync-energy ratio.

Environment knobs (CI smoke uses all three):
  * ``REPRO_BENCH_ENERGY_NS``  — comma-separated PE counts (default
    ``64,256,1024``; CI runs 64).  The Pareto front runs at the
    largest listed N.
  * ``REPRO_BENCH_ENERGY_5G_N`` — cluster size for the 5G section
    (default 1024).
  * ``BENCH_ENERGY_JSON``       — output path (default
    ``<repo>/BENCH_energy.json``).
"""
import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core import barrier, fiveg, sweep, tuning
from repro.core.topology import DEFAULT, TeraPoolConfig

from . import timing

KEY = jax.random.PRNGKey(0)
N_TRIALS = 8
DELAY = 0.0   # simultaneous arrival: the contention-dominated regime

_NS = tuple(int(x) for x in os.environ.get(
    "REPRO_BENCH_ENERGY_NS", "64,256,1024").split(","))
_5G_N = int(os.environ.get("REPRO_BENCH_ENERGY_5G_N", "1024"))
_OUT = Path(os.environ.get(
    "BENCH_ENERGY_JSON",
    Path(__file__).resolve().parent.parent / "BENCH_energy.json"))


def _cfg(n: int) -> TeraPoolConfig:
    return DEFAULT if n == DEFAULT.n_pes else TeraPoolConfig(n_pes=n)


def _mode_stack(cfg):
    return [("central", barrier.central_counter(cfg=cfg)),
            (f"tree{min(32, cfg.n_pes)}",
             barrier.kary_tree(min(32, cfg.n_pes), cfg=cfg)),
            ("hw", barrier.hw_event_unit(cfg=cfg))]


def _energy_vs_n(rows: list) -> dict:
    out = {}
    for n in _NS:
        cfg = _cfg(n)
        names, scheds = zip(*_mode_stack(cfg))
        res, steady_us, compile_us = timing.measure(
            lambda: sweep.sweep_schedules(
                KEY, list(scheds), delays=(DELAY,), n_trials=N_TRIALS,
                cfg=cfg), iters=2)
        span = jnp.mean(res.span_cycles, axis=-1)[:, 0]
        energy = jnp.mean(res.energy, axis=-1)[:, 0]
        entry = {}
        for i, name in enumerate(names):
            entry[name] = {"span_cycles": round(float(span[i]), 1),
                           "energy_pj": round(float(energy[i]), 1)}
        sw = [i for i, nm in enumerate(names) if nm != "hw"]
        hw = names.index("hw")
        entry["hw_dominates_software"] = bool(
            all(float(span[hw]) < float(span[i])
                and float(energy[hw]) < float(energy[i]) for i in sw))
        out[f"N={n}"] = entry
        rows.append((f"energy_modes_N{n}", steady_us,
                     f"hwE={entry['hw']['energy_pj']}pJ", compile_us))
    return out


def _pareto(rows: list) -> dict:
    n = max(_NS)
    cfg = _cfg(n)
    scheds = tuning.all_schedules(n, cfg, prune="none")
    res, steady_us, compile_us = timing.measure(
        lambda: tuning.tune_barrier(KEY, n, delays=(DELAY,),
                                    n_trials=N_TRIALS, cfg=cfg,
                                    schedules=scheds), iters=1)
    front = tuning.pareto_front(res)
    hw_res = sweep.sweep_schedules(
        KEY, [barrier.hw_event_unit(cfg=cfg)], delays=(DELAY,),
        n_trials=N_TRIALS, cfg=cfg)
    hw_span = float(jnp.mean(hw_res.span_cycles, axis=-1)[0, 0])
    hw_energy = float(jnp.mean(hw_res.energy, axis=-1)[0, 0])
    entry = {
        "n_pes": n,
        "delay": DELAY,
        "n_schedules": len(scheds),
        "n_software_points": len(front),
        "front": [{"name": p.name,
                   "span_cycles": round(p.mean_span, 1),
                   "energy_pj": round(p.mean_energy, 1)} for p in front],
        "hw_point": {"name": "hw",
                     "span_cycles": round(hw_span, 1),
                     "energy_pj": round(hw_energy, 1)},
        "hw_dominates_front": bool(all(
            hw_span < p.mean_span and hw_energy < p.mean_energy
            for p in front)),
    }
    rows.append((f"energy_pareto_N{n}", steady_us,
                 f"{len(front)}pts", compile_us))
    return entry


def _fiveg(rows: list) -> dict:
    cfg = _cfg(_5G_N)
    modes = ("central", "tree", "hw")
    out, steady_us, compile_us = timing.measure(
        lambda: fiveg.compare_barriers(KEY, modes=modes, cfg=cfg), iters=1)
    entry = {"n_pes": _5G_N}
    for mode in modes:
        r = out[mode]
        entry[mode] = {
            "total_cycles": round(float(r.total_cycles), 1),
            "sync_energy_pj": round(float(r.sync_energy), 1),
            "energy_fraction": round(float(r.energy_fraction), 5),
            "stage_schedule": r.stage_schedule,
        }
    entry["speedup_hw"] = round(float(out["speedup_hw"]), 3)
    entry["energy_ratio_hw"] = round(float(out["energy_ratio_hw"]), 2)
    entry["energy_ratio_tree"] = round(float(out["energy_ratio_tree"]), 2)
    rows.append((f"energy_5g_N{_5G_N}", steady_us,
                 f"ratio={entry['energy_ratio_hw']}", compile_us))
    return entry


def run():
    rows = []
    record = {"energy_per_barrier": _energy_vs_n(rows),
              "pareto": _pareto(rows),
              "fiveg": _fiveg(rows)}
    _OUT.write_text(json.dumps(record, indent=2) + "\n")
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
