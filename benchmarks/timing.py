"""Shared benchmark timing: compile vs steady-state, not dispatch.

JAX dispatch is asynchronous — ``fn()`` returns a future-like array, so
naive ``perf_counter`` pairs measure Python dispatch, not compute, and
the first call silently folds in tracing + XLA compilation.  Every
driver times through :func:`measure`:

* call 1 (blocked on) is timed, then ``warmup`` further calls retire
  any remaining lazy work;
* ``iters`` calls, each blocked with ``jax.block_until_ready`` on the
  whole result pytree -> ``steady_us`` (mean per call);
* ``compile_us`` = first call minus steady state (floored at 0): the
  estimated one-off trace + XLA-compile overhead.  When the program
  was already warm from an earlier measurement it reads ~0 instead of
  masquerading as a fresh compile.

The two are reported as separate CSV columns so a compile regression
can't masquerade as a compute win (or vice versa).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Tuple

import jax


def measure(fn: Callable[[], Any], *, warmup: int = 1,
            iters: int = 3) -> Tuple[Any, float, float]:
    """Time ``fn`` properly; returns ``(result, steady_us, compile_us)``."""
    t0 = time.perf_counter()
    result = jax.block_until_ready(fn())
    first_us = (time.perf_counter() - t0) * 1e6

    for _ in range(warmup):
        jax.block_until_ready(fn())

    t0 = time.perf_counter()
    for _ in range(iters):
        result = jax.block_until_ready(fn())
    steady_us = (time.perf_counter() - t0) * 1e6 / max(iters, 1)
    return result, steady_us, max(0.0, first_us - steady_us)
