"""Fig. 7: the 5G OFDM + beamforming application under central / tree /
partial barriers (cycles, serial speedup, speedup over central).

``simulate_app`` is a jitted ``lax.scan`` over epochs, so each compile
covers a whole ~25-barrier pipeline and any radix value.  The scan
*length* (epoch count) is static, so each distinct (sync mode,
n_epochs) pair compiles once; sweeping the radix or timing constants
at fixed shape reuses the compiled program."""
import jax

from repro.core import fiveg

from . import timing

KEY = jax.random.PRNGKey(3)


def tuned_schedule_rows():
    """The winning per-epoch trees of every tuned sync mode at the
    fine-grained 16-FFT configuration (the one fig_tuned_tree /
    fig_placement report), read off ``FiveGResult.stage_schedule`` /
    ``.global_schedule`` (tuned modes pick their own trees, so the
    report must say WHICH tree each mode ran)."""
    app = fiveg.FiveGConfig(n_rx=16, ffts_per_round=1)
    rows = []
    for mode in ("tuned", "tuned_partial", "placed", "workload"):
        res = fiveg.simulate_app(KEY, app, sync=mode)
        rows.append((f"fig7_{mode}_stage_sched", 0.0,
                     res.stage_schedule, 0.0))
        rows.append((f"fig7_{mode}_global_sched", 0.0,
                     res.global_schedule, 0.0))
    return rows


def run():
    rows = []
    for n_rx in (16, 32, 64):
        for fpr in (1, 4):
            if (n_rx // 4) % fpr:
                continue
            app = fiveg.FiveGConfig(n_rx=n_rx, ffts_per_round=fpr)
            res, steady_us, compile_us = timing.measure(
                lambda: fiveg.compare_barriers(KEY, app, radix=32))
            tag = f"fig7_nrx{n_rx}_fpr{fpr}"
            rows.append((f"{tag}_cycles_central", steady_us,
                         round(float(res["central"].total_cycles)),
                         compile_us))
            rows.append((f"{tag}_cycles_partial32", steady_us,
                         round(float(res["partial"].total_cycles)),
                         compile_us))
            rows.append((f"{tag}_speedup_partial", steady_us,
                         round(float(res["speedup_partial"]), 3),
                         compile_us))
            rows.append((f"{tag}_syncfrac_partial", steady_us,
                         round(float(res["partial"].sync_fraction), 4),
                         compile_us))
            rows.append((f"{tag}_speedup_serial", steady_us,
                         round(float(res["partial"].speedup_serial), 1),
                         compile_us))
    return rows + tuned_schedule_rows()
