"""Fig. 7: the 5G OFDM + beamforming application under central / tree /
partial barriers (cycles, serial speedup, speedup over central)."""
import time

import jax

from repro.core import fiveg

KEY = jax.random.PRNGKey(3)


def run():
    rows = []
    for n_rx in (16, 32, 64):
        for fpr in (1, 4):
            if (n_rx // 4) % fpr:
                continue
            app = fiveg.FiveGConfig(n_rx=n_rx, ffts_per_round=fpr)
            t0 = time.perf_counter()
            res = fiveg.compare_barriers(KEY, app, radix=32)
            us = (time.perf_counter() - t0) * 1e6
            tag = f"fig7_nrx{n_rx}_fpr{fpr}"
            rows.append((f"{tag}_cycles_central", us,
                         round(float(res["central"].total_cycles))))
            rows.append((f"{tag}_cycles_partial32", us,
                         round(float(res["partial"].total_cycles))))
            rows.append((f"{tag}_speedup_partial", us,
                         round(float(res["speedup_partial"]), 3)))
            rows.append((f"{tag}_syncfrac_partial", us,
                         round(float(res["partial"].sync_fraction), 4)))
            rows.append((f"{tag}_speedup_serial", us,
                         round(float(res["partial"].speedup_serial), 1)))
    return rows
