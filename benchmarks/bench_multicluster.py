"""Multi-cluster scale-out benchmark: hierarchical barriers at
2048-16384 PEs through the generalized telescope core.

Three headline measurements per machine size, written to
``BENCH_multicluster.json`` at the repo root:

* **Sweep throughput** — the joint intra-cluster x inter-cluster
  schedule space (:func:`repro.core.tuning.multicluster_schedules`)
  plus the flat baselines, swept through the one-compile engine;
  steady-state us per grid point.
* **Hierarchical vs flat** — simulated span cycles of the best
  hierarchical multi-cluster tree against the flat central-counter
  barrier (every PE hammering one remote bank) and the best
  cluster-oblivious uniform radix, on the same arrival draws.  The
  paper's Sec. 5 fine-tuning argument, reproduced at scale-out size.
* **2-D vs schedule-only sharding** — wall-clock of the same
  ``sweep_arrivals`` grid under the 2-D (schedule x kernel) device
  mesh versus the largest schedule-only mesh, with the visible-device
  and physical-core counts recorded alongside (on a single physical
  CPU the fake-device meshes time-slice one core, so the honest win
  to watch there is device *coverage*, not wall-clock).
* **Width-table speedup** — the telescope core under the generalized
  cumulative-quotient widths versus the conservative ``N >> i``
  fallback on the same hierarchical stack: the pure win from this
  PR's per-schedule width derivation.

Environment knobs (CI smoke uses both):
  * ``REPRO_BENCH_MC_NS``        — comma-separated TOTAL PE counts
    (default ``2048,4096,16384``; CI runs 128).
  * ``BENCH_MULTICLUSTER_JSON``  — output path (default
    ``<repo>/BENCH_multicluster.json``).
"""
import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core import barrier, sweep, tuning
from repro.core.topology import TeraPoolConfig, multi_cluster

from . import timing

KEY = jax.random.PRNGKey(0)
N_CLUSTERS = 4
DELAYS = (0.0, 512.0)
N_TRIALS = 4
N_KERNELS = 8
# Beyond this many joint compositions, fall back to the curated stack
# (uniform-radix intra shapes + the hierarchy-segment tree) so 16384-PE
# tables stay memory-bounded.
MAX_STACK = 192

_NS = tuple(int(x) for x in os.environ.get(
    "REPRO_BENCH_MC_NS", "2048,4096,16384").split(","))
_OUT = Path(os.environ.get(
    "BENCH_MULTICLUSTER_JSON",
    Path(__file__).resolve().parent.parent / "BENCH_multicluster.json"))


def _machine(n_total: int):
    return multi_cluster(TeraPoolConfig(n_pes=n_total // N_CLUSTERS),
                         n_clusters=N_CLUSTERS)


def _hier_schedules(cfg):
    """The joint hierarchical space, curated down when it outgrows the
    memory budget."""
    full = tuning.multicluster_compositions(cfg)
    if len(full) <= MAX_STACK:
        comps = full
    else:
        ppc = cfg.pes_per_cluster
        intra = [tuple(barrier.kary_tree(r, n_pes=ppc, cfg=cfg).sizes)
                 for r in (2, 4, 8, 16) if ppc % r == 0]
        intra.append(tuple(tuning._hier_segments(ppc, cfg)))
        comps = tuning.multicluster_compositions(
            cfg, intra=sorted(set(intra)))
    return [barrier.mixed_radix_tree(c, cfg=cfg) for c in comps]


def _flat_schedules(cfg):
    """Cluster-oblivious baselines: the central counter and the best-N
    uniform radices over the whole machine."""
    flats = [barrier.mixed_radix_tree((cfg.n_pes,), cfg=cfg)]
    for r in (4, 8, 16):
        if cfg.n_pes % r == 0:
            flats.append(barrier.kary_tree(r, n_pes=cfg.n_pes, cfg=cfg))
    return flats


def _bench_machine(n_total: int, rows: list) -> dict:
    cfg = _machine(n_total)
    hier = _hier_schedules(cfg)
    flats = _flat_schedules(cfg)
    stack = hier + flats

    # -- sweep throughput + hier-vs-flat spans (one swept grid) ----------
    res, steady_us, compile_us = timing.measure(
        lambda: sweep.sweep_schedules(
            KEY, stack, delays=DELAYS, n_trials=N_TRIALS,
            cfg=cfg).span_cycles, iters=2)
    n_points = len(stack) * len(DELAYS) * N_TRIALS
    spans = jnp.mean(res, axis=-1)            # (S, D)
    # Span at delay 0 (simultaneous arrival): the contention-dominated
    # regime where the central counter serializes all N atomics on one
    # remote bank and tree shape matters most.
    hier_best = float(jnp.min(spans[:len(hier), 0]))
    central = float(spans[len(hier), 0])
    flat_uniform_best = float(jnp.min(spans[len(hier):, 0]))
    entry = {
        "n_pes": n_total,
        "n_clusters": N_CLUSTERS,
        "n_schedules": len(stack),
        "sweep": {
            "points": n_points,
            "steady_us": round(steady_us, 1),
            "compile_us": round(compile_us, 1),
            "us_per_point": round(steady_us / n_points, 3),
        },
        "hier_vs_flat": {
            "hier_best_span": round(hier_best, 1),
            "central_span": round(central, 1),
            "uniform_best_span": round(flat_uniform_best, 1),
            "speedup_vs_central": round(central / hier_best, 2),
            "speedup_vs_uniform": round(flat_uniform_best / hier_best, 2),
        },
    }
    rows.append((f"mc_sweep_N{n_total}", steady_us / n_points,
                 f"{n_points}pts", compile_us))
    rows.append((f"mc_hier_vs_central_N{n_total}", 0.0,
                 entry["hier_vs_flat"]["speedup_vs_central"], 0.0))

    # -- 2-D vs schedule-only sharding on an arrival grid ----------------
    devs = jax.devices()
    sub = hier[:4] if len(hier) >= 4 else hier
    arrivals = jax.random.uniform(
        KEY, (N_KERNELS, N_TRIALS, cfg.n_pes), jnp.float32, 0.0, 512.0)
    ds, dk = sweep._mesh_shape(len(devs), len(sub), N_KERNELS)
    sched_only = sweep._grid_devices(len(sub), True, devs)
    timed = {}
    for label, kwargs in (
            ("grid_2d", dict(shard=True)),
            ("sched_only", dict(shard=True,
                                devices=devs[:ds] if sched_only is None
                                else sched_only)),
            ("unsharded", dict(shard=False))):
        _, t_us, c_us = timing.measure(
            lambda kw=kwargs: sweep.sweep_arrivals(
                arrivals, sub, cfg=cfg, **kw).span_cycles, iters=2)
        timed[label] = {"steady_us": round(t_us, 1),
                        "compile_us": round(c_us, 1)}
    timed["grid_2d"]["mesh"] = [ds, dk]
    timed["sched_only"]["mesh"] = [ds, 1]
    entry["sharding"] = {
        "n_devices": len(devs),
        "physical_cpus": os.cpu_count(),
        "n_schedules": len(sub),
        "n_kernels": N_KERNELS,
        "devices_used_2d": ds * dk,
        "devices_used_sched_only": ds,
        "speedup_2d_vs_sched_only": round(
            timed["sched_only"]["steady_us"]
            / timed["grid_2d"]["steady_us"], 2),
        **timed,
    }
    rows.append((f"mc_shard2d_N{n_total}",
                 timed["grid_2d"]["steady_us"],
                 f"{ds}x{dk}mesh", timed["grid_2d"]["compile_us"]))

    # -- generalized vs fallback telescope widths ------------------------
    # Measured on the hierarchy-matched stack: its cumulative-quotient
    # widths shrink by the real level sizes (8x, 16x, ...), where the
    # fallback only halves.  (The full sweep stack above contains
    # radix-2 compositions whose widths ARE the fallback, so its
    # stacked maximum cannot tighten by construction.)
    hseg = tuning.multicluster_compositions(
        cfg, intra=[tuple(tuning._hier_segments(cfg.pes_per_cluster,
                                                cfg))])
    tables = barrier.stack_tables(
        [barrier.mixed_radix_tree(c, cfg=cfg) for c in hseg], cfg)
    one = jax.random.uniform(KEY, (cfg.n_pes,), jnp.float32, 0.0, 512.0)
    tight = barrier.telescope_widths(tables, cfg.n_pes)
    loose = barrier.default_widths(cfg.n_pes, len(tight) - 1)
    per_width = {}
    for label, w in (("tight", tight), ("fallback", loose)):
        _, t_us, c_us = timing.measure(
            lambda w=w: sweep._schedule_stack(
                tables, one, cfg, "telescope", w).span_cycles, iters=2)
        per_width[label] = {"steady_us": round(t_us, 1),
                            "compile_us": round(c_us, 1)}
    entry["widths"] = {
        "sum_tight": int(sum(tight)),
        "sum_fallback": int(sum(loose)),
        "speedup": round(per_width["fallback"]["steady_us"]
                         / per_width["tight"]["steady_us"], 2),
        **per_width,
    }
    rows.append((f"mc_widths_N{n_total}", per_width["tight"]["steady_us"],
                 entry["widths"]["speedup"],
                 per_width["tight"]["compile_us"]))
    return entry


def run():
    rows = []
    record = {}
    for n in _NS:
        record[f"N={n}"] = _bench_machine(n, rows)
    _OUT.write_text(json.dumps(record, indent=2) + "\n")
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
