"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived,compile_us`` CSV rows.  Steady-state
time (``us_per_call``) and one-off compile time are separate columns so
dispatch/compile overhead can't masquerade as compute (see
:mod:`benchmarks.timing`); modules that report no timing emit 0.0.

``python -m benchmarks.run --list`` prints every registered benchmark
with a one-line description; ``python -m benchmarks.run <tag>`` runs
just that one.
"""
import sys


def _modules():
    from . import (bench_core, bench_energy, bench_faults,
                   bench_multicluster, bench_resilience, bench_serving,
                   collectives_bench, fig4_random_delay, fig5_kernel_cdf,
                   fig6_kernel_colormap, fig7_5g_app, fig_placement,
                   fig_tuned_tree, fig_workload_tuned, roofline_table)
    return [("fig4", fig4_random_delay), ("fig5", fig5_kernel_cdf),
            ("fig6", fig6_kernel_colormap), ("fig7", fig7_5g_app),
            ("tuned", fig_tuned_tree),
            ("placement", fig_placement),
            ("workload", fig_workload_tuned),
            ("core", bench_core),
            ("multicluster", bench_multicluster),
            ("energy", bench_energy),
            ("collectives", collectives_bench),
            ("resilience", bench_resilience),
            ("faults", bench_faults),
            ("serving", bench_serving),
            ("roofline", roofline_table)]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    mods = _modules()
    if only == "--list":
        for tag, mod in mods:
            desc = (mod.__doc__ or "").strip().splitlines()[0]
            print(f"{tag:14s} {desc}")
        return
    print("name,us_per_call,derived,compile_us")
    for tag, mod in mods:
        if only and tag != only:
            continue
        for row in mod.run():
            name, us, derived = row[:3]
            compile_us = row[3] if len(row) > 3 else 0.0
            print(f"{name},{us:.1f},{derived},{compile_us:.1f}", flush=True)


if __name__ == "__main__":
    main()
