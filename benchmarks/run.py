"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (scaffold contract).
"""
import sys


def main() -> None:
    from . import (collectives_bench, fig4_random_delay, fig5_kernel_cdf,
                   fig6_kernel_colormap, fig7_5g_app, roofline_table)
    mods = [("fig4", fig4_random_delay), ("fig5", fig5_kernel_cdf),
            ("fig6", fig6_kernel_colormap), ("fig7", fig7_5g_app),
            ("collectives", collectives_bench),
            ("roofline", roofline_table)]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for tag, mod in mods:
        if only and tag != only:
            continue
        for name, us, derived in mod.run():
            print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
