"""Serving benchmark: what the request-serving daemon adds on top of
the raw sweep, and what request coalescing buys back.

Three measurements, written to ``BENCH_serving.json`` at the repo root:

* **Tail latency** — p50/p99 wall time of a tuning request served
  end-to-end through :class:`repro.runtime.serving.TuningServer`
  (submit -> coalescing window -> batched dispatch -> response) vs the
  raw unbatched :func:`repro.core.sweep.sweep_arrivals` the server
  wraps.  Every request uses a FRESH arrival trace so nothing is
  memoized and every response rides the exact tier.  The acceptance
  bar is p99 added latency <= 10% over the raw sweep at N=1024.
* **Batching efficiency** — the same requests submitted concurrently
  coalesce into one dispatch on the kernel axis; we report
  requests/dispatch and the per-request amortized latency.
* **Degraded-tier latency** — how fast the closed-form fallback
  answers when the deadline has already expired (the floor of the
  degradation ladder).

Environment knobs (CI smoke shrinks the cluster):
  * ``REPRO_BENCH_SERVING_N`` — cluster size (default ``1024``).
  * ``REPRO_BENCH_SERVING_REQUESTS`` — sequential requests timed for
    the tail (default ``8``).
  * ``BENCH_SERVING_JSON`` — artifact path (default
    ``<repo>/BENCH_serving.json``).
"""
import json
import os
import time
from pathlib import Path

import jax
import numpy as np

from repro.core import sweep, tuning
from repro.core.topology import DEFAULT, TeraPoolConfig
from repro.runtime.serving import (ServerConfig, TuneRequest,
                                   TuningServer, fallback_uniform)

KEY = jax.random.PRNGKey(0)
N = int(os.environ.get("REPRO_BENCH_SERVING_N", "1024"))
N_REQUESTS = int(os.environ.get("REPRO_BENCH_SERVING_REQUESTS", "8"))
N_TRIALS = 4
_OUT = Path(os.environ.get(
    "BENCH_SERVING_JSON",
    Path(__file__).resolve().parent.parent / "BENCH_serving.json"))


def _cfg() -> TeraPoolConfig:
    return DEFAULT if N == DEFAULT.n_pes else TeraPoolConfig(n_pes=N)


def _trace(i: int) -> np.ndarray:
    return np.asarray(
        300.0 * jax.random.uniform(jax.random.fold_in(KEY, i),
                                   (N_TRIALS, N)), np.float32)


def _pct(xs, q) -> float:
    return float(np.percentile(np.asarray(xs), q))


def run():
    rows = []
    cfg = _cfg()
    prune = "none" if N <= 256 else "hierarchy"
    scheds = tuning.all_schedules(N, cfg, prune=prune)
    srv_cfg = ServerConfig(batch_window=0.005, default_n_trials=N_TRIALS)

    # Pre-draw every trace so trace generation never sits inside a
    # timed (or coalescing) window.
    raw_traces = [_trace(100 + i) for i in range(N_REQUESTS)]
    seq_traces = [_trace(200 + i) for i in range(N_REQUESTS)]
    batch_traces = [_trace(300 + i) for i in range(N_REQUESTS)]

    # Warm both dispatch shapes — the single-request path THROUGH the
    # server (its stacked (1, T, N) layout + winner selection) and the
    # N_REQUESTS-kernel stack — so XLA compile time hits neither the
    # raw nor the served numbers.
    sweep.sweep_arrivals(_trace(0), scheds, cfg)
    warm = np.stack([_trace(1000 + i) for i in range(N_REQUESTS)])
    sweep.sweep_arrivals(warm, scheds, cfg,
                         kernels=tuple(f"w{i}" for i in range(N_REQUESTS)))
    with TuningServer(srv_cfg) as srv:
        srv.tune(TuneRequest(arrivals=_trace(999)), timeout=3600)
    warm_srv = TuningServer(ServerConfig(batch_window=0.05,
                                         default_n_trials=N_TRIALS,
                                         max_batch=N_REQUESTS),
                            start=False)
    warm_tickets = [warm_srv.submit(
        TuneRequest(arrivals=_trace(1100 + i))) for i in range(N_REQUESTS)]
    warm_srv.start()
    for t in warm_tickets:
        t.result(timeout=3600)
    warm_srv.close()

    # Tail latency, raw vs served, INTERLEAVED so OS/allocator jitter
    # lands on both paths alike (a tail estimate from so few samples is
    # the max; an outlier must not be charged to one side only).  Raw
    # is the unbatched engine; served is submit + coalescing window +
    # single-kernel dispatch + respond, on fresh traces every time so
    # nothing is memoized and every response rides the exact tier.
    raw_s, serve_s = [], []
    with TuningServer(srv_cfg) as srv:
        for raw_trace, seq_trace in zip(raw_traces, seq_traces):
            t0 = time.perf_counter()
            jax.block_until_ready(
                sweep.sweep_arrivals(raw_trace, scheds, cfg).span_cycles)
            raw_s.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            resp = srv.tune(TuneRequest(arrivals=seq_trace), timeout=3600)
            serve_s.append(time.perf_counter() - t0)
            assert resp.provenance == "batched", resp
        seq_stats = srv.stats
    raw_med, raw_p99 = _pct(raw_s, 50), _pct(raw_s, 99)
    p50, p99 = _pct(serve_s, 50), _pct(serve_s, 99)
    added_p99 = 100.0 * (p99 - raw_p99) / raw_p99

    # Batching efficiency: the same load submitted concurrently fuses
    # into one kernel-axis dispatch.  The worker starts only after the
    # whole queue is in place (no context manager: __enter__ starts it).
    srv = TuningServer(ServerConfig(batch_window=0.05,
                                    default_n_trials=N_TRIALS,
                                    max_batch=N_REQUESTS), start=False)
    try:
        t0 = time.perf_counter()
        tickets = [srv.submit(TuneRequest(arrivals=trace))
                   for trace in batch_traces]
        srv.start()
        for t in tickets:
            assert t.result(timeout=3600).provenance == "batched"
        batch_wall = time.perf_counter() - t0
        efficiency = srv.stats.batch_efficiency
    finally:
        srv.close()
    amortized = batch_wall / N_REQUESTS

    # Degradation floor: an already-expired deadline answers from the
    # closed-form model without touching the sweep engine.
    with TuningServer(srv_cfg) as srv:
        t0 = time.perf_counter()
        resp = srv.tune(TuneRequest(arrivals=_trace(400), deadline=0.0),
                        timeout=60)
        degraded_s = time.perf_counter() - t0
        assert resp.provenance == "degraded", resp
    fallback_uniform(N, cfg)     # keep the analytic model exercised

    record = {
        "n_pes": N,
        "n_requests": N_REQUESTS,
        "n_schedules": len(scheds),
        "raw_sweep_us": round(raw_med * 1e6, 1),
        "raw_p99_us": round(raw_p99 * 1e6, 1),
        "serve_p50_us": round(p50 * 1e6, 1),
        "serve_p99_us": round(p99 * 1e6, 1),
        "added_p99_pct": round(added_p99, 2),
        "accept_added_p99_le_10pct": bool(added_p99 <= 10.0),
        "batch_wall_us": round(batch_wall * 1e6, 1),
        "batch_amortized_us": round(amortized * 1e6, 1),
        "batch_efficiency_req_per_dispatch": round(efficiency, 2),
        "batch_speedup_vs_sequential": round(
            float(np.sum(serve_s)) / batch_wall, 2),
        "degraded_floor_us": round(degraded_s * 1e6, 1),
        "sequential_stats": {
            "batches": seq_stats.batches,
            "exact": seq_stats.exact,
            "cache_hits": seq_stats.cache_hits,
        },
    }
    _OUT.write_text(json.dumps(record, indent=2) + "\n")
    rows.append((f"serving_raw_N{N}", raw_med * 1e6,
                 f"{len(scheds)}sched", 0.0))
    rows.append((f"serving_p99_N{N}", p99 * 1e6,
                 f"added={added_p99:.1f}%", 0.0))
    rows.append((f"serving_batched_N{N}", amortized * 1e6,
                 f"eff={efficiency:.1f}req/dispatch", 0.0))
    rows.append((f"serving_degraded_N{N}", degraded_s * 1e6,
                 "tier=fallback", 0.0))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
