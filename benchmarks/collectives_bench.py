"""Layer-B mapping benchmark: flat (central-counter) vs hierarchical
(tree) vs radix-factored gradient synchronization, measured as lowered
collective wire bytes on an 8-device mesh (subprocess: jax locks the
device count at first init)."""
import json
import os
import subprocess
import sys
import time
from pathlib import Path

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import collectives
from repro.launch import hlo_analysis

out = {}
G = 1 << 20  # 1 Mi-element f32 gradient

def wire(fn, mesh, in_spec, axis_names):
    g = collectives.shard_map_compat(fn, mesh, in_spec, in_spec, axis_names)
    x = jnp.ones((G,), jnp.float32)
    hlo = jax.jit(g).lower(x).compile().as_text()
    return hlo_analysis.analyze(hlo).collective_bytes

try:
    mesh2 = jax.make_mesh((2, 4), ("pod", "data"),
                          axis_types=(jax.sharding.AxisType.Auto,) * 2)
except (AttributeError, TypeError):  # jax < 0.5
    mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
out["flat"] = wire(lambda x: collectives.psum_chain(x, ("data", "pod")),
                   mesh2, P(), {"pod", "data"})
out["hier"] = wire(
    lambda x: collectives.gather_param(
        jax.lax.psum(jax.lax.psum_scatter(x, "data", scatter_dimension=0,
                                          tiled=True), "pod"),
        ("data",), 0),
    mesh2, P(), {"pod", "data"})
meshr = collectives.make_factored_mesh(2, model=1, data=4, multi_pod=True)
out["radix2"] = wire(
    lambda x: collectives.tree_psum(x, ("pod", "data0", "data1")),
    meshr, P(), {"pod", "data0", "data1"})
print(json.dumps(out))
"""


def run():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parent.parent / "src")
    env.pop("XLA_FLAGS", None)
    t0 = time.perf_counter()
    r = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                       capture_output=True, text=True, timeout=600)
    us = (time.perf_counter() - t0) * 1e6
    rows = []
    if r.returncode == 0:
        data = json.loads(r.stdout.strip().splitlines()[-1])
        for k, v in data.items():
            rows.append((f"collectives_sync_{k}_wireMiB", us,
                         round(v / 2 ** 20, 2)))
        if data.get("flat"):
            rows.append(("collectives_hier_vs_flat_ratio", us,
                         round(data["hier"] / data["flat"], 3)))
    else:
        rows.append(("collectives_bench_failed", us,
                     r.stderr[-120:].replace(",", ";")))
    return rows
