"""Counter placement as a tuned design axis (beyond-figure): the
hierarchy-pruned composition space crossed with every named placement
strategy runs composition x placement x delay x trial through ONE
compiled scanned core, and the per-strategy best-span curves quantify
the contention-vs-latency trade-off the paper's Sec. 5 locality
argument implies — leaf-local is conflict-free at minimal latency,
group-hub/central pay same-bank serialization, tile-interleaved pays
cluster-class hops.  A second block reports the 5G application under
``sync="placed"`` (jointly tuned schedule + counter->bank mapping)
next to the schedule-only tuner.
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import barrier, barrier_sim, fiveg, placement, topology, tuning

from . import timing

KEY = jax.random.PRNGKey(0)
DELAYS = [0.0, 128.0, 512.0, 2048.0]
N_TRIALS = 4   # composition x placement (128 x 4 at N=1024) dominates
BANKING_FACTORS = (2, 4, 8)   # sensitivity micro-sweep (default is 4)


def placement_tradeoff():
    res, steady_us, compile_us = timing.measure(
        lambda: tuning.tune_barrier(KEY, delays=DELAYS, n_trials=N_TRIALS,
                                    prune="hierarchy",
                                    placements=placement.STRATEGIES),
        warmup=0, iters=1)
    n_points = len(res.schedules)
    rows = [("placement_sweep_grid", steady_us,
             f"{n_points}x{len(DELAYS)}x{N_TRIALS}", compile_us)]
    spans = jnp.mean(res.span_cycles, axis=-1)          # (S, D)
    by_strategy = {
        strat: [i for i, p in enumerate(res.placements)
                if p.strategy == strat]
        for strat in placement.STRATEGIES}
    for j, delay in enumerate(res.delays.tolist()):
        d = int(delay)
        base = None
        for strat in placement.STRATEGIES:
            idx = jnp.asarray(by_strategy[strat])
            col = spans[idx, j]
            k = int(jnp.argmin(col))
            i = by_strategy[strat][k]
            best = float(col[k])
            if strat == "leaf_local":
                base = best
            shared = sum(res.placements[i].shared_bank_counters())
            rows.append((f"placement_delay{d}_{strat}", 0.0,
                         round(best, 1), 0.0))
            rows.append((f"placement_delay{d}_{strat}_sched", 0.0,
                         res.schedules[i].name, 0.0))
            rows.append((f"placement_delay{d}_{strat}_shared", 0.0,
                         shared, 0.0))
            if strat != "leaf_local":
                rows.append((f"placement_delay{d}_{strat}_penalty", 0.0,
                             round(best / base, 3), 0.0))
    return rows


def placed_5g():
    app = fiveg.FiveGConfig(n_rx=16, ffts_per_round=1)
    res, steady_us, compile_us = timing.measure(
        lambda: fiveg.compare_barriers(
            KEY, app, radix=32, modes=("central", "partial", "tuned",
                                       "placed")),
        warmup=0, iters=1)
    rows = [("placement_5g_compare", steady_us, "4modes", compile_us)]
    for mode in ("partial", "tuned", "placed"):
        rows.append((f"placement_5g_speedup_{mode}", 0.0,
                     round(float(res[f"speedup_{mode}"]), 3), 0.0))
        rows.append((f"placement_5g_syncfrac_{mode}", 0.0,
                     round(float(res[mode].sync_fraction), 4), 0.0))
    return rows


def banking_sensitivity():
    """Banking-factor sensitivity of the placed tuner: re-derive every
    strategy's banks/latencies under banking_factor in {2, 4, 8} and
    re-run the joint composition x placement sweep.  The named
    strategies allocate banks PROPORTIONALLY to the factor (leaf-local
    spreads by span*bf, hub/central pile on the same class), so their
    spans are bf-invariant — itself the finding — while a FIXED
    32-bank-stride heap allocator (tuned for the default factor 4) IS
    bf-sensitive: at bf=2 it wraps the halved bank space (64 same-bank
    counter pairs on the leaf level), at bf=4 every counter lands in
    its accessors' own Tile, at bf=8 it strides past the Tile into
    group/cluster classes (extra rows)."""
    rows = []
    sizes = (8, 16, 8)
    arrs = {0: jnp.zeros((4, 1024)),
            512: 512.0 * jax.random.uniform(KEY, (4, 1024))}
    for bf in BANKING_FACTORS:
        cfg = dataclasses.replace(topology.DEFAULT, banking_factor=bf)
        res, steady_us, compile_us = timing.measure(
            lambda: tuning.tune_barrier(KEY, delays=(0.0, 512.0),
                                        n_trials=2, prune="hierarchy",
                                        placements=placement.STRATEGIES,
                                        cfg=cfg),
            warmup=0, iters=1)
        rows.append((f"banking_bf{bf}_sweep", steady_us,
                     f"{len(res.schedules)}x2x2", compile_us))
        spans = jnp.mean(res.span_cycles, axis=-1)      # (S, D)
        for j, delay in enumerate(res.delays.tolist()):
            d = int(delay)
            for strat in placement.STRATEGIES:
                idx = jnp.asarray([i for i, p in enumerate(res.placements)
                                   if p.strategy == strat])
                best = float(jnp.min(spans[idx, j]))
                rows.append((f"banking_bf{bf}_delay{d}_{strat}", 0.0,
                             round(best, 1), 0.0))
        s = barrier.mixed_radix_tree(sizes, cfg=cfg)
        pl = placement.explicit_placement(s, bank_offsets=[0] * 3,
                                          bank_strides=[32] * 3, cfg=cfg)
        for d, arr in arrs.items():
            span = float(jnp.mean(barrier_sim.simulate(
                arr, s, cfg, placement=pl).span_cycles))
            rows.append((f"banking_bf{bf}_delay{d}_heap_stride32", 0.0,
                         round(span, 1), 0.0))
        rows.append((f"banking_bf{bf}_heap_shared", 0.0,
                     sum(pl.shared_bank_counters()), 0.0))
    return rows


def run():
    return placement_tradeoff() + placed_5g() + banking_sensitivity()
