"""End-to-end training driver: data pipeline -> sharded train step ->
fault-tolerant runner with atomic checkpoints.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --scale 100m --steps 300

``--scale 100m`` trains a ~100M-parameter qwen3-family model (slow on
one CPU core; the default ``10m`` finishes a few hundred steps in
minutes).  Restarting the same command resumes from the latest
checkpoint.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import optim
from repro.core import collectives
from repro.data import DataConfig, batch_for_model
from repro.launch import mesh as mesh_mod, steps
from repro.models import ModelConfig, init_params
from repro.runtime import FaultConfig, FaultTolerantRunner

SCALES = {
    "10m": dict(n_layers=6, d_model=320, n_heads=8, n_kv_heads=4,
                d_ff=1280, vocab_size=8192),
    "100m": dict(n_layers=12, d_model=640, n_heads=10, n_kv_heads=5,
                 d_ff=2560, vocab_size=32000),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="10m", choices=sorted(SCALES))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="runs/train_lm")
    ap.add_argument("--sync", default="hierarchical",
                    choices=["hierarchical", "flat"])
    args = ap.parse_args()

    cfg = ModelConfig(name=f"lm-{args.scale}", family="dense",
                      qk_norm=True, attn_chunk=128, micro_batches=1,
                      **SCALES[args.scale])
    print(f"model: {cfg.param_count() / 1e6:.1f}M params")
    mesh = mesh_mod.make_smoke_mesh()
    sync = (collectives.FLAT if args.sync == "flat"
            else collectives.HIERARCHICAL)
    ocfg = optim.OptConfig.from_model(cfg, lr=args.lr, warmup_steps=20,
                                      total_steps=args.steps)
    dcfg = DataConfig(seed=0, seq_len=args.seq, global_batch=args.batch,
                      vocab_size=cfg.vocab_size)

    with jax.set_mesh(mesh):
        fn, art = steps.build_train_step(cfg, mesh, sync=sync,
                                         opt_cfg=ocfg)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt_state = optim.init(params, ocfg)

        def step_fn(state, batch):
            p, s = state
            p, s, metrics = fn(p, s, batch)
            return (p, s), metrics

        def batch_fn(step):
            return jax.tree.map(jnp.asarray,
                                batch_for_model(cfg, dcfg, step))

        t0 = time.time()

        def on_step(st):
            if st.step % 20 == 0:
                rate = (st.step + 1) * dcfg.seq_len * dcfg.global_batch \
                    / max(time.time() - t0, 1e-9)
                print(f"step {st.step:5d}  loss {st.metrics['loss']:.4f}"
                      f"  grad_norm {st.metrics['grad_norm']:.3f}"
                      f"  tok/s {rate:,.0f}", flush=True)

        runner = FaultTolerantRunner(
            FaultConfig(ckpt_dir=args.ckpt, ckpt_every=100),
            step_fn=step_fn, batch_fn=batch_fn,
            state_template=(params, opt_state))
        start = runner.resume_step()
        if start:
            print(f"resuming from checkpointed step {start}")
        runner.run(args.steps, on_step=on_step)
    print("done.")


if __name__ == "__main__":
    main()
