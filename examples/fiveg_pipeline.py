"""The paper's 5G application end to end, twice:

1. *Simulated on TeraPool* — the cycle-level model reproducing Fig. 7
   (central vs tree vs partial barriers).
2. *Executed on the TPU kernel stack* — the radix-4 FFT stage kernels +
   beamforming matmul from repro.kernels actually process an OFDM
   batch (interpret mode on CPU), validated against numpy.

    PYTHONPATH=src python examples/fiveg_pipeline.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import fiveg
from repro.kernels import ops, ref


def simulate():
    print("== TeraPool simulation (Fig. 7) ==")
    key = jax.random.PRNGKey(0)
    for n_rx in (16, 32, 64):
        app = fiveg.FiveGConfig(n_rx=n_rx, ffts_per_round=4)
        res = fiveg.compare_barriers(key, app, radix=32)
        print(f" N_RX={n_rx:3d}: central={float(res['central'].total_cycles):9.0f}cy"
              f"  partial32={float(res['partial'].total_cycles):9.0f}cy"
              f"  speedup={float(res['speedup_partial']):.2f}x"
              f"  sync={float(res['partial'].sync_fraction) * 100:.1f}%")


def execute():
    print("\n== TPU kernel pipeline (OFDM demod + beamforming) ==")
    rng = np.random.default_rng(0)
    n_rx, n_sc, n_beams = 8, 1024, 4
    # antenna streams (time domain)
    re = jnp.asarray(rng.standard_normal((n_rx, n_sc)), jnp.float32)
    im = jnp.asarray(rng.standard_normal((n_rx, n_sc)), jnp.float32)

    # OFDM demodulation: radix-4 DIF FFT per antenna (pallas stages)
    fr, fi = ops.fft4(re, im)
    idx = np.asarray(ref.digit_reverse_indices(n_sc))
    want = np.fft.fft(np.asarray(re) + 1j * np.asarray(im), axis=-1)
    np.testing.assert_allclose(np.asarray(fr)[:, idx], want.real,
                               rtol=1e-3, atol=2e-3)
    print(f" FFT: {n_rx} x {n_sc}-pt radix-4 OK (max err "
          f"{np.max(np.abs(np.asarray(fr)[:, idx] - want.real)):.2e})")

    # beamforming: (n_beams x n_rx) @ (n_rx x n_sc), pallas matmul
    coef = jnp.asarray(rng.standard_normal((n_beams, n_rx)), jnp.float32)
    beams_r = ops.matmul(coef, fr)
    beams_i = ops.matmul(coef, fi)
    np.testing.assert_allclose(beams_r, np.asarray(coef) @ np.asarray(fr),
                               rtol=1e-4, atol=1e-3)
    print(f" beamforming: {n_beams} beams x {n_sc} subcarriers OK")
    print(" output power per beam:",
          np.round(np.mean(np.asarray(beams_r) ** 2
                           + np.asarray(beams_i) ** 2, axis=1), 1))


if __name__ == "__main__":
    simulate()
    execute()
