"""Batched serving demo: prefill a batch of prompts, then decode new
tokens step by step against the KV cache.

    PYTHONPATH=src python examples/serve_lm.py --tokens 32
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import mesh as mesh_mod, steps
from repro.models import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b",
                    help="architecture id (reduced smoke config is used)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    if cfg.family == "encoder":
        raise SystemExit("encoder-only architectures do not decode")
    mesh = mesh_mod.make_smoke_mesh()
    max_len = args.prompt_len + args.tokens
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)

    with jax.set_mesh(mesh):
        prefill, _ = steps.build_prefill_step(cfg, mesh, batch=args.batch,
                                              seq_len=max_len)
        decode, _ = steps.build_decode_step(cfg, mesh, batch=args.batch,
                                            max_len=max_len)
        prompts = jax.random.randint(key, (args.batch, max_len), 0,
                                     cfg.vocab_size)
        batch_in = {"tokens": prompts}
        if cfg.frontend == "vision":
            batch_in["img_embeds"] = jnp.zeros(
                (args.batch, cfg.n_frontend_tokens, cfg.d_model),
                jnp.bfloat16)
        t0 = time.time()
        logits, caches = prefill(params, batch_in)
        jax.block_until_ready(logits)
        print(f"prefill {args.batch}x{max_len}: "
              f"{(time.time() - t0) * 1e3:.0f} ms")

        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        generated = [tok]
        t0 = time.time()
        for i in range(args.tokens - 1):
            pos = jnp.full((args.batch,), args.prompt_len + i, jnp.int32)
            logits, caches = decode(params, caches, tok[:, None], pos)
            tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            generated.append(tok)
        jax.block_until_ready(tok)
        dt = time.time() - t0
        print(f"decode {args.tokens - 1} steps: {dt * 1e3:.0f} ms "
              f"({(args.tokens - 1) * args.batch / dt:.1f} tok/s)")
        out = jnp.stack(generated, axis=1)
        print("generated token ids (first sequence):",
              out[0].tolist()[:16], "...")


if __name__ == "__main__":
    main()
