"""Barrier-radix tuning — the paper's key methodology as a library call.

Given a workload's arrival-time distribution, pick the synchronization
schedule (radix + partial groups) that minimizes total runtime, exactly
as Sec. 5 tunes Fig. 6/7.

    PYTHONPATH=src python examples/barrier_tuning.py
"""
import jax
import jax.numpy as jnp

from repro.core import barrier, barrier_sim, workloads

KEY = jax.random.PRNGKey(0)


def tune(arrival_fn, n_trials: int = 8):
    """Returns (best_radix, cycles_by_radix)."""
    keys = jax.random.split(KEY, n_trials)
    totals = {}
    for radix in barrier.all_radices():
        sched = barrier.kary_tree(radix)
        t = 0.0
        for k in keys:
            t += float(barrier_sim.simulate(arrival_fn(k), sched).exit_time)
        totals[radix] = t / n_trials
    return min(totals, key=totals.get), totals


def main():
    suite = workloads.benchmark_suite()
    print(f"{'kernel':10s} {'input':12s} {'best radix':>10s} "
          f"{'vs worst':>9s} {'vs central':>10s}")
    for kernel, dims in suite.items():
        for label, fn in dims.items():
            best, totals = tune(fn)
            worst = max(totals.values())
            print(f"{kernel:10s} {label:12s} {best:10d} "
                  f"{worst / totals[best]:8.2f}x "
                  f"{totals[1024] / totals[best]:9.2f}x")
    print("\nThe spread reproduces the paper's Fig. 6c: 1.1-1.7x from "
          "radix selection alone.")


if __name__ == "__main__":
    main()
