"""Barrier tuning — the paper's key methodology as a library call, now
over the FULL mixed-radix schedule space.

Two layers of the tuner API:

1. `tuning.tune_barrier` sweeps EVERY composition of log2(N) into
   power-of-two level sizes (512 schedules at N=1024) x arrival scatter
   x trial through one compiled program, and `tuning.best_per_delay`
   reads off the winning composition against the best uniform radix —
   the generalized Fig. 4a tuning step.
2. `tuning.sweep_workloads` replays every kernel's MEASURED arrival
   batch (`workloads.arrival_batch`) under the whole schedule stack in
   one compiled call — the per-kernel tuning of Fig. 6 conditioned on
   the real arrival shapes, and `tuning.best_per_kernel` reads off the
   winner per kernel against the best uniform radix.
3. `tuning.tune_barrier(placements=...)` crosses the composition space
   with the counter-placement strategies of `repro.core.placement`:
   WHERE each counter lives (which L1 bank) becomes a tuned knob, and
   co-located counters pay real same-bank serialization.

    PYTHONPATH=src python examples/barrier_tuning.py
"""
import jax
import jax.numpy as jnp

from repro.core import placement, sweep, tuning

KEY = jax.random.PRNGKey(0)
DELAYS = (0.0, 128.0, 512.0, 2048.0)


def tune_random_delay():
    """The generalized Fig. 4a step: best composition per scatter.

    Runs on the telescoping simulator core (the default; pass
    ``core="scan"`` for the full-width oracle core, or ``trial_chunk=``
    to bound grid memory — both are bit-for-bit identical)."""
    res = tuning.tune_barrier(KEY, delays=DELAYS, n_trials=4)
    print(f"swept {len(res.schedules)} compositions x {len(DELAYS)} "
          f"delays in one compile")
    print("winners: " + ", ".join(
        f"d={int(d)}:{name}" for d, name in
        zip(res.delays.tolist(), sweep.best_schedule_per_delay(res))))
    print(f"{'delay':>6s} {'tuned schedule':>16s} {'span':>8s} "
          f"{'best uniform':>14s} {'span':>8s} {'gain':>6s}")
    for p in tuning.best_per_delay(res):
        print(f"{p.delay:6.0f} {p.schedule.name:>16s} "
              f"{p.mean_span:8.1f} {p.uniform_schedule.name:>14s} "
              f"{p.uniform_span:8.1f} {p.uniform_span / p.mean_span:5.2f}x")
    front = tuning.pareto_schedules(res)
    print(f"\nPareto front across delays ({len(front)} schedules): "
          + ", ".join(s.name for s in front))


def tune_kernels():
    """Per-kernel schedule selection on MEASURED arrivals (Fig. 6c,
    workload-conditioned edition): every kernel's arrival batch x every
    composition through one compiled call."""
    res = tuning.sweep_workloads(KEY, n_trials=4)
    central = res.names.index("1024")
    spans = res.mean_span                              # (S, K)
    print(f"\nswept {len(res.schedules)} compositions x "
          f"{len(res.kernels)} kernels x 4 trials in one compile")
    print(f"{'kernel':22s} {'tuned schedule':>16s} "
          f"{'vs uniform':>10s} {'vs central':>10s}")
    for j, p in enumerate(tuning.best_per_kernel(res)):
        c = float(spans[central, j])
        print(f"{p.kernel:22s} {p.schedule.name:>16s} "
              f"{p.uniform_span / p.mean_span:9.3f}x "
              f"{c / p.mean_span:9.2f}x")


def tune_placement():
    """Counter placement as the second design axis: the contention /
    latency trade-off behind the paper's leaf-local policy."""
    res = tuning.tune_barrier(KEY, delays=DELAYS, n_trials=4,
                              prune="hierarchy",
                              placements=placement.STRATEGIES)
    spans = jnp.mean(res.span_cycles, axis=-1)
    print(f"\nswept {len(res.schedules)} (composition, placement) points "
          f"x {len(DELAYS)} delays in one compile")
    print(f"{'delay':>6s} " + " ".join(f"{s:>18s}"
                                       for s in placement.STRATEGIES))
    for j, d in enumerate(res.delays.tolist()):
        cells = []
        for strat in placement.STRATEGIES:
            idx = [i for i, p in enumerate(res.placements)
                   if p.strategy == strat]
            best = float(jnp.min(spans[jnp.asarray(idx), j]))
            cells.append(f"{best:18.1f}")
        print(f"{d:6.0f} " + " ".join(cells))
    print("(mean span, best composition per strategy: co-locating "
          "counters on hub/central banks pays same-bank serialization; "
          "interleaving pays cluster-class hops)")


def main():
    tune_random_delay()
    tune_kernels()
    tune_placement()
    print("\nThe uniform-radix spread reproduces Fig. 6c (1.1-1.7x from "
          "radix selection); the tuned compositions squeeze the "
          "remaining few percent the paper attributes to hierarchy-"
          "matched trees, and the placement sweep shows the paper's "
          "leaf-local counter allocation is the dominant corner of the "
          "contention-vs-latency trade-off.")


if __name__ == "__main__":
    main()
