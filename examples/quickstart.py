"""Quickstart: the paper's barrier simulator + a tiny training run.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import configs, optim
from repro.core import barrier, barrier_sim, fiveg
from repro.data import DataConfig, batch_for_model
from repro.models import init_params, loss_fn


def barrier_demo():
    print("== TeraPool barrier simulator (paper Fig. 4a) ==")
    key = jax.random.PRNGKey(0)
    for delay in (0.0, 2048.0):
        spans = {r: float(barrier_sim.mean_span_cycles(
            key, barrier.kary_tree(r), delay, n_trials=8))
            for r in (2, 16, 32, 256, 1024)}
        best = min(spans, key=spans.get)
        print(f" max_delay={int(delay):5d}: "
              + "  ".join(f"r{r}={v:7.1f}" for r, v in spans.items())
              + f"   -> best radix {best}")

    print("\n== 5G OFDM + beamforming (paper Fig. 7) ==")
    res = fiveg.compare_barriers(key, fiveg.FiveGConfig(
        n_rx=16, ffts_per_round=1), radix=32)
    print(f" radix-32 partial barriers: {float(res['speedup_partial']):.2f}x"
          f" over central counter; sync fraction "
          f"{float(res['partial'].sync_fraction) * 100:.1f}%")


def train_demo(steps: int = 20):
    print("\n== 20 training steps on a reduced qwen3-family model ==")
    cfg = configs.get_smoke("qwen3_4b")
    dcfg = DataConfig(seed=0, seq_len=64, global_batch=8,
                      vocab_size=cfg.vocab_size)
    ocfg = optim.OptConfig.from_model(cfg, lr=3e-3, warmup_steps=5)
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = optim.init(params, ocfg)

    @jax.jit
    def step(p, s, b):
        (loss, m), g = jax.value_and_grad(
            lambda q: loss_fn(q, cfg, b), has_aux=True)(p)
        p2, s2 = optim.update(g, s, p, ocfg)
        return p2, s2, loss

    for i in range(steps):
        batch = jax.tree.map(jnp.asarray, batch_for_model(cfg, dcfg, i))
        params, state, loss = step(params, state, batch)
        if i % 5 == 0 or i == steps - 1:
            print(f" step {i:3d}  loss {float(loss):.4f}")


if __name__ == "__main__":
    barrier_demo()
    train_demo()
