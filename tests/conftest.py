"""Test harness config.

Smoke/unit tests run on the default single CPU device.  The
distribution tests (tests/test_distribution.py) need several devices;
``tests/test_system.py::test_distribution_suite_multidevice`` re-runs
them in a subprocess with REPRO_MULTIDEV=1, which this conftest turns
into an 8-device host platform BEFORE jax initializes.

When ``hypothesis`` is unavailable (the container does not ship it and
nothing may be pip-installed), a minimal deterministic stand-in is
registered instead: ``@given`` runs each property test over a fixed
pseudo-random sample of the strategies (seeded, so failures reproduce).
Only the slice of the API the suite uses is provided — ``given``,
``settings``, ``strategies.integers/floats/sampled_from``.
"""
import os

if os.environ.get("REPRO_MULTIDEV"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

try:
    import hypothesis  # noqa: F401
except ImportError:
    import random
    import sys
    import types

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    def _integers(lo, hi):
        return _Strategy(lambda rng: rng.randint(lo, hi))

    def _floats(lo, hi):
        return _Strategy(lambda rng: rng.uniform(lo, hi))

    def _sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rng: rng.choice(items))

    def _settings(max_examples=10, deadline=None, **_kw):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn
        return deco

    def _given(*strategies):
        # NB: the wrapper must expose a ZERO-arg signature (no
        # functools.wraps / __wrapped__), else pytest reads the original
        # parameters and demands fixtures for them.
        def deco(fn):
            def wrapper():
                n = getattr(wrapper, "_stub_max_examples", 10)
                rng = random.Random(0)
                for _ in range(n):
                    fn(*[s.sample(rng) for s in strategies])
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper.hypothesis_stub = True
            return wrapper
        return deco

    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.sampled_from = _sampled_from
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
