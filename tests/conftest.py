"""Test harness config.

Smoke/unit tests run on the default single CPU device.  The
distribution tests (tests/test_distribution.py) need several devices;
``tests/test_system.py::test_distribution_suite_multidevice`` re-runs
them in a subprocess with REPRO_MULTIDEV=1, which this conftest turns
into an 8-device host platform BEFORE jax initializes.
"""
import os

if os.environ.get("REPRO_MULTIDEV"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))
