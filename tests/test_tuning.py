"""Mixed-radix schedule algebra + exhaustive tuner: enumeration
properties, bit-for-bit equivalence of EVERY composition with the seed
per-level oracle, the one-compile property of the full 512-composition
sweep, and the acceptance bar that the tuned best matches or beats the
best uniform radix at every delay."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import barrier, barrier_sim, fiveg, sweep, tuning
from repro.core.topology import DEFAULT

KEY = jax.random.PRNGKey(0)
DELAYS = (0.0, 128.0, 512.0, 2048.0)


# ---------------------------------------------------------------------------
# Schedule algebra.
# ---------------------------------------------------------------------------

def test_mixed_radix_tree_structure():
    s = barrier.mixed_radix_tree((8, 16, 8))
    assert s.n_pes == 1024 and s.n_levels == 3
    assert s.sizes == (8, 16, 8)
    assert [l.span for l in s.levels] == [8, 128, 1024]
    assert [l.latency for l in s.levels] == [DEFAULT.lat_tile,
                                             DEFAULT.lat_group,
                                             DEFAULT.lat_cluster]
    assert s.radix == 0 and s.name == "8x16x8"


def test_mixed_radix_tree_validation():
    with pytest.raises(ValueError):
        barrier.mixed_radix_tree(())
    with pytest.raises(ValueError):
        barrier.mixed_radix_tree((8, 1, 16))      # identity level
    with pytest.raises(ValueError):
        barrier.mixed_radix_tree((8, 16), n_pes=1024)   # product mismatch
    with pytest.raises(ValueError):
        barrier.mixed_radix_tree((1024, 4))       # exceeds the cluster
    # Non-power-of-two level sizes are part of the algebra now: any
    # ordered factorization into sizes >= 2 builds a valid tree.
    s = barrier.mixed_radix_tree((8, 3))
    assert s.n_pes == 24 and s.sizes == (8, 3)


def test_named_schedules_are_thin_wrappers():
    """kary/central/partial reduce to mixed_radix_tree compositions."""
    k = barrier.kary_tree(8)
    assert k == barrier.mixed_radix_tree((2, 8, 8, 8))
    assert k.radix == 8 and k.name == "2x8x8x8"
    c = barrier.central_counter()
    assert c == barrier.mixed_radix_tree((1024,))
    assert c.radix == 1024
    p = barrier.partial_barrier(256, 16)
    assert p == barrier.mixed_radix_tree((16, 16), partial=True)
    assert p.partial and p.name == "16x16p"


def test_compose_rederives_spans_and_latencies():
    tile = barrier.kary_tree(8, n_pes=8)       # 1-cycle counters alone
    upper = barrier.mixed_radix_tree((16, 8))  # Groups then cluster
    s = barrier.compose(tile, upper)
    assert s.sizes == (8, 16, 8)               # 8 * (16x8) = 1024 PEs
    assert s == barrier.mixed_radix_tree((8, 16, 8))
    # upper's leaf level had span 16 (latency 3); composed under the
    # tile its span is 128 and its root moves to the cluster class.
    assert [l.latency for l in s.levels] == [1, 3, 5]


def test_describe_and_names():
    assert "mixed-radix" in barrier.describe(
        barrier.mixed_radix_tree((8, 16, 8)))
    assert "radix-8" in barrier.describe(barrier.kary_tree(8))
    assert "central counter" in barrier.describe(barrier.central_counter())


# ---------------------------------------------------------------------------
# Enumeration.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_pes", [64, 256, 1024])
def test_composition_count_and_coverage(n_pes):
    comps = tuning.enumerate_compositions(n_pes)
    m = int(math.log2(n_pes))
    assert len(comps) == 2 ** (m - 1)          # 512 at N=1024
    assert len(set(comps)) == len(comps)
    for c in comps:
        assert math.prod(c) == n_pes
    # every uniform-radix (first-level-adapted) shape is in the space
    for r in barrier.all_radices(n_pes):
        assert barrier.kary_tree(r, n_pes=n_pes).sizes in set(comps), r


def test_hierarchy_pruning_subset():
    full = set(tuning.enumerate_compositions(1024))
    pruned = tuning.hierarchy_compositions(1024)
    assert len(pruned) == 128                  # 4 x 8 x 4 segments
    boundaries = {DEFAULT.pes_per_tile,
                  DEFAULT.pes_per_tile * DEFAULT.tiles_per_group}
    for c in pruned:
        assert c in full
        spans = set(np.cumprod(c).tolist())
        assert boundaries <= spans             # never straddles a class
    assert (8, 16, 8) in set(pruned)


# ---------------------------------------------------------------------------
# Every composition == the seed per-level oracle, bit for bit.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_pes", [64, 256, 1024])
def test_every_composition_matches_oracle(n_pes):
    schedules = tuning.all_schedules(n_pes)
    arr = 512.0 * jax.random.uniform(KEY, (n_pes,))
    res = sweep.simulate_schedules(arr, schedules)   # one compiled stack
    for i, s in enumerate(schedules):
        ref = barrier_sim.simulate_reference(arr, s)
        for name, a, b in zip(ref._fields, ref,
                              (res.exit_time[i], res.last_arrival[i],
                               res.span_cycles[i], res.mean_residency[i])):
            assert float(a) == float(b), (n_pes, s.name, name)


# ---------------------------------------------------------------------------
# Acceptance: one compile for the full grid; tuned >= best uniform.
# ---------------------------------------------------------------------------

def test_full_tuner_sweep_compiles_once_and_beats_uniform():
    """The acceptance-criterion sweep: all 512 compositions x 4 delays x
    trials at N=1024 trace the scanned core exactly once, and the tuned
    best matches or beats the best uniform radix at every delay."""
    jax.clear_caches()
    barrier_sim.TRACE_COUNTS.clear()
    res = tuning.tune_barrier(jax.random.PRNGKey(42), delays=DELAYS,
                              n_trials=4)
    jax.block_until_ready(res.span_cycles)
    assert res.span_cycles.shape == (512, 4, 4)
    assert barrier_sim.core_traces() == 1

    for p in tuning.best_per_delay(res):
        assert p.mean_span <= p.uniform_span, (p.delay, p.schedule.name)
    # a hierarchy-pruned sweep over the same cluster reuses the compile
    res2 = tuning.tune_barrier(jax.random.PRNGKey(7), delays=DELAYS,
                               n_trials=4, prune="hierarchy")
    jax.block_until_ready(res2.span_cycles)
    assert res2.span_cycles.shape == (128, 4, 4)
    # pruned stack has a different leading dim -> one extra trace, not
    # one per schedule
    assert barrier_sim.core_traces() == 2


def test_best_per_delay_and_pareto():
    res = tuning.tune_barrier(KEY, n_pes=64, delays=(0.0, 2048.0),
                              n_trials=4)
    best = tuning.best_per_delay(res)
    assert len(best) == 2
    # scattered arrivals favour the central counter (paper Fig. 4a)
    assert best[1].schedule == barrier.central_counter(64)
    front = tuning.pareto_schedules(res)
    assert best[0].schedule in front and best[1].schedule in front
    # the front never contains a schedule dominated by another
    spans = np.asarray(res.mean_span)
    idx = [res.schedules.index(s) for s in front]
    for i in idx:
        assert not any(np.all(spans[j] <= spans[i])
                       and np.any(spans[j] < spans[i])
                       for j in range(len(res.schedules)))


def test_sweep_schedules_rejects_mixed_sizes():
    with pytest.raises(ValueError):
        sweep.sweep_schedules(KEY, [barrier.kary_tree(2, n_pes=64),
                                    barrier.kary_tree(2, n_pes=128)])


# ---------------------------------------------------------------------------
# Tuned 5G sync modes.
# ---------------------------------------------------------------------------

def test_5g_tuned_modes():
    app = fiveg.FiveGConfig(n_rx=16, ffts_per_round=1)
    res = fiveg.compare_barriers(
        KEY, app, radix=32,
        modes=("central", "partial", "tuned", "tuned_partial"))
    # tuned partial stage trees match or beat the paper's fixed radix-32
    # partial strategy (the tuner searches a superset of its schedules)
    assert float(res["speedup_tuned_partial"]) >= \
        float(res["speedup_partial"]) - 1e-3
    assert float(res["speedup_tuned_partial"]) > 1.4
    # scanned app == unrolled oracle under a tuned schedule
    got = fiveg.simulate_app(KEY, app, sync="tuned_partial")
    ref = fiveg.simulate_app_reference(KEY, app, sync="tuned_partial")
    for name, a, b in zip(got._fields, got, ref):
        if isinstance(a, str):   # winning-schedule names, not timings
            assert a == b and a, name
            continue
        assert float(a) == pytest.approx(float(b), rel=1e-6), name
