"""Telescoping simulator core: bit-for-bit equivalence with the
full-width scanned core and both reference oracles (property-style
random compositions x placements x arrival scatters), the shrinking-
width invariant's canonicalization guard, the one-compile property of
telescoped grids, bounded-memory trial chunking, schedule-axis device
sharding, and the best-schedule-per-delay selector."""
import math
import os
import random
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import barrier, barrier_sim, placement, sweep, tuning
from repro.core.topology import DEFAULT

KEY = jax.random.PRNGKey(0)
REPO = Path(__file__).resolve().parent.parent


def _random_composition(rng: random.Random, n_pes: int) -> tuple:
    """A uniformly drawn composition of log2(n_pes) into pow2 sizes."""
    m = int(math.log2(n_pes))
    sizes = []
    while m:
        p = rng.randint(1, m)
        sizes.append(1 << p)
        m -= p
    return tuple(sizes)


def _assert_bitwise(got, want, ctx):
    for name, a, b in zip(got._fields, got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{ctx}: {name}")


# ---------------------------------------------------------------------------
# Exhaustive stack equivalence: telescope == scan for EVERY composition
# (and every placement strategy), through the compiled stacks.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_pes", [64, 256, 1024])
def test_telescope_matches_scan_all_compositions(n_pes):
    schedules = tuning.all_schedules(n_pes)
    arr = 512.0 * jax.random.uniform(KEY, (n_pes,))
    tele = sweep.simulate_schedules(arr, schedules, core="telescope")
    scan = sweep.simulate_schedules(arr, schedules, core="scan")
    _assert_bitwise(tele, scan, f"N={n_pes}")


@pytest.mark.parametrize("n_pes", [64, 256])
def test_telescope_matches_scan_all_placements(n_pes):
    schedules = tuning.all_schedules(n_pes)
    scheds, placs = tuning._cross_placements(
        schedules, placement.STRATEGIES, DEFAULT)
    arr = 300.0 * jax.random.uniform(jax.random.PRNGKey(7), (n_pes,))
    tele = sweep.simulate_schedules(arr, scheds, placements=placs,
                                    core="telescope")
    scan = sweep.simulate_schedules(arr, scheds, placements=placs,
                                    core="scan")
    _assert_bitwise(tele, scan, f"N={n_pes} placed")


# ---------------------------------------------------------------------------
# Property suite: random composition x placement x arrival scatter,
# telescoped core vs scanned core vs the reference oracles.
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1),
       st.sampled_from([64, 256, 1024]),
       st.sampled_from([None, "leaf_local", "tile_interleaved",
                        "group_hub", "central", "explicit"]),
       st.floats(0.0, 4096.0))
def test_random_composition_placement_equivalence(seed, n_pes, strat,
                                                  delay):
    """Random mixed-radix composition, random counter placement
    (including adversarial explicit offset/stride encodings), random
    arrival scatter: the telescoped core must agree bit for bit with
    the scanned core AND with the matching reference oracle."""
    rng = random.Random(seed)
    sched = barrier.mixed_radix_tree(_random_composition(rng, n_pes))
    if strat is None:
        plc = None
    elif strat == "explicit":
        offs = [rng.randrange(DEFAULT.n_banks)
                for _ in range(sched.n_levels)]
        strides = [rng.choice([0, 1, 4, 32])
                   for _ in range(sched.n_levels)]
        plc = placement.explicit_placement(sched, offs, strides)
    else:
        plc = placement.place_counters(sched, strat)
    arr = delay * jax.random.uniform(jax.random.PRNGKey(seed), (n_pes,))

    tele = barrier_sim.simulate(arr, sched, placement=plc,
                                core="telescope")
    scan = barrier_sim.simulate(arr, sched, placement=plc, core="scan")
    ctx = (n_pes, sched.name, strat, round(delay, 1))
    _assert_bitwise(tele, scan, ctx)

    if plc is None:
        ref = barrier_sim.simulate_reference(arr, sched)
        _assert_bitwise(tele, ref, ctx)
    elif n_pes <= 256:   # the numpy bank-queue oracle is per-episode
        ref = placement.simulate_placed_reference(arr, sched, plc)
        for name, a, b in zip(tele._fields, tele, ref):
            assert float(a) == pytest.approx(float(b), rel=1e-6), \
                (ctx, name)


def test_telescope_batched_matches_reference():
    sched = barrier.mixed_radix_tree((8, 16, 8))
    arr = 2048.0 * jax.random.uniform(KEY, (4, 3, 1024))
    got = barrier_sim.simulate(arr, sched, core="telescope")
    ref = barrier_sim.simulate_reference(arr, sched)
    assert got.exit_time.shape == (4, 3)
    _assert_bitwise(got, ref, "batched")


# ---------------------------------------------------------------------------
# Canonicalization: the tail-only-padding invariant the N/2^i survivor
# bound relies on.
# ---------------------------------------------------------------------------

def test_validate_tail_padding_accepts_canonical_tables():
    for s in (barrier.kary_tree(8), barrier.central_counter(),
              barrier.mixed_radix_tree((8, 16, 8))):
        t = barrier.level_table(s)
        assert barrier.validate_tail_padding(t) is t
    stacked = barrier.stack_tables([barrier.kary_tree(r)
                                    for r in (2, 32, 1024)])
    assert barrier.validate_tail_padding(stacked) is stacked


def test_validate_tail_padding_rejects_mid_padding():
    t = barrier.level_table(barrier.kary_tree(2, n_pes=64))
    bad = t._replace(
        group_sizes=jnp.asarray([2, 1, 2, 2, 2, 4], jnp.int32))
    with pytest.raises(ValueError, match="tail-padded"):
        barrier.validate_tail_padding(bad)
    with pytest.raises(ValueError, match="tail-padded"):
        barrier_sim.simulate_table(jnp.zeros((64,)), bad)


def test_validate_tail_padding_rejects_nonzero_padding_levels():
    t = barrier.level_table(barrier.kary_tree(8, n_pes=64))
    bad = t._replace(instr_cycles=t.instr_cycles.at[-1].set(3.0))
    with pytest.raises(ValueError, match="zero latency"):
        barrier.validate_tail_padding(bad)


# ---------------------------------------------------------------------------
# One-compile property of the telescoped core (grids share one trace).
# ---------------------------------------------------------------------------

def test_telescope_one_compile_composition_placement_grid():
    """The full composition x placement x delay x trial grid traces the
    TELESCOPED core exactly once — and never touches the scan core."""
    jax.clear_caches()
    barrier_sim.TRACE_COUNTS.clear()
    res = tuning.tune_barrier(jax.random.PRNGKey(3), n_pes=64,
                              delays=(0.0, 128.0, 2048.0), n_trials=4,
                              placements=placement.STRATEGIES,
                              core="telescope")
    jax.block_until_ready(res.span_cycles)
    assert res.span_cycles.shape == (128, 3, 4)
    assert barrier_sim.TRACE_COUNTS["telescope_core"] == 1
    assert barrier_sim.TRACE_COUNTS["scan_core"] == 0

    # different schedules/placements, same shapes: no retrace
    res2 = tuning.tune_barrier(jax.random.PRNGKey(4), n_pes=64,
                               delays=(1.0, 64.0, 512.0), n_trials=4,
                               placements=placement.STRATEGIES,
                               core="telescope")
    jax.block_until_ready(res2.span_cycles)
    assert barrier_sim.TRACE_COUNTS["telescope_core"] == 1


def test_core_selector_validates():
    with pytest.raises(ValueError, match="unknown simulator core"):
        barrier_sim.core_fn("warp")
    assert barrier_sim.core_fn("scan") is barrier_sim._scan_core
    assert barrier_sim.core_fn("telescope") is barrier_sim._telescope_core
    assert barrier_sim.DEFAULT_CORE in barrier_sim.CORES


# ---------------------------------------------------------------------------
# Memory-bounded sweeps: trial chunking is bit-for-bit invisible.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("trial_chunk", [1, 3, 4, 16, 64])
def test_trial_chunking_bitforbit_sweep(trial_chunk):
    full = sweep.sweep_barrier(KEY, radices=(2, 8, 64), n_pes=64,
                               delays=(0.0, 512.0), n_trials=16)
    part = sweep.sweep_barrier(KEY, radices=(2, 8, 64), n_pes=64,
                               delays=(0.0, 512.0), n_trials=16,
                               trial_chunk=trial_chunk)
    _assert_bitwise(
        sweep.BarrierResult(full.exit_time, full.last_arrival,
                            full.span_cycles, full.mean_residency,
                            full.energy, full.completed,
                            full.abandoned_pes, full.timed_out_levels),
        (part.exit_time, part.last_arrival, part.span_cycles,
         part.mean_residency, part.energy, part.completed,
         part.abandoned_pes, part.timed_out_levels),
        f"chunk={trial_chunk}")


def test_trial_chunking_bitforbit_arrivals():
    scheds = tuning.all_schedules(64)
    arr = 200.0 * jax.random.uniform(KEY, (3, 8, 64))
    full = sweep.sweep_arrivals(arr, scheds)
    part = sweep.sweep_arrivals(arr, scheds, trial_chunk=3)
    np.testing.assert_array_equal(np.asarray(full.span_cycles),
                                  np.asarray(part.span_cycles))
    np.testing.assert_array_equal(np.asarray(full.exit_time),
                                  np.asarray(part.exit_time))
    with pytest.raises(ValueError):
        sweep.sweep_arrivals(arr, scheds, trial_chunk=0)


def test_tuner_trial_chunk_passthrough():
    full = tuning.tune_barrier(KEY, 64, delays=(0.0, 512.0), n_trials=8)
    part = tuning.tune_barrier(KEY, 64, delays=(0.0, 512.0), n_trials=8,
                               trial_chunk=2)
    np.testing.assert_array_equal(np.asarray(full.span_cycles),
                                  np.asarray(part.span_cycles))


# ---------------------------------------------------------------------------
# Schedule-axis device sharding (8-device subprocess; transparent
# single-device fallback is what every other test in the suite runs).
# ---------------------------------------------------------------------------

def test_single_device_shard_fallback():
    assert sweep._grid_devices(32, shard=True) is None or \
        len(jax.devices()) > 1
    assert sweep._grid_devices(32, shard=False) is None


def test_sharded_sweep_multidevice():
    """Under 8 host devices the schedule axis shards via shard_map and
    the results match the unsharded path bit for bit."""
    env = dict(os.environ)
    env["REPRO_MULTIDEV"] = "1"
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        + os.environ.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = str(REPO / "src")
    script = """
import jax
import numpy as np
from repro.core import barrier_sim, placement, sweep, tuning

assert len(jax.devices()) == 8, jax.devices()
key = jax.random.PRNGKey(0)
# 32 compositions x 4 strategies = 128 points: divisible by 8.
barrier_sim.TRACE_COUNTS.clear()
sharded = tuning.tune_barrier(key, 64, delays=(0.0, 512.0), n_trials=4,
                              placements=placement.STRATEGIES)
jax.block_until_ready(sharded.span_cycles)
assert sweep._grid_devices(128, shard=True) is not None
# the sharded grid still traces the core exactly once
assert barrier_sim.core_traces() == 1, dict(barrier_sim.TRACE_COUNTS)
plain = tuning.tune_barrier(key, 64, delays=(0.0, 512.0), n_trials=4,
                            placements=placement.STRATEGIES, shard=False)
np.testing.assert_array_equal(np.asarray(sharded.span_cycles),
                              np.asarray(plain.span_cycles))
# indivisible stacks fall back transparently
odd = tuning.tune_barrier(key, 64, delays=(0.0,), n_trials=2,
                          schedules=tuning.all_schedules(64)[:3])
assert odd.span_cycles.shape == (3, 1, 2)
print("sharded sweep ok")
"""
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "sharded sweep ok" in r.stdout


# ---------------------------------------------------------------------------
# best_schedule_per_delay: canonical names for mixed-radix stacks.
# ---------------------------------------------------------------------------

def test_best_schedule_per_delay_names():
    res = tuning.tune_barrier(KEY, n_pes=64, delays=(0.0, 2048.0),
                              n_trials=4)
    names = sweep.best_schedule_per_delay(res)
    assert len(names) == 2
    assert all(isinstance(x, str) for x in names)
    # same argmin as best_per_delay, expressed as canonical names
    best = tuning.best_per_delay(res)
    assert names == tuple(p.schedule.name for p in best)
    # scattered arrivals favour the central counter (paper Fig. 4a),
    # where best_radix_per_delay's 0 placeholder would be meaningless
    assert names[1] == "64"


def test_best_schedule_per_delay_carries_placement_suffix():
    res = tuning.tune_barrier(KEY, n_pes=64, delays=(2048.0,),
                              n_trials=4, placements=("central",))
    names = sweep.best_schedule_per_delay(res)
    assert names[0].endswith("@central")
