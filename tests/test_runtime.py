"""Runtime resilience primitives: elastic mesh shrinking (2-D data x
model and the 1-D schedule axis), the fault-tolerant runner's straggler
watchdog and restart-from-checkpoint semantics, supervisor backoff +
history carry, and checkpoint-store robustness (corrupt manifests,
stale .tmp pruning) — the previously untested seed modules the
resilient sweep runtime is built on."""
import json
import os
import time
from pathlib import Path

import pytest

from repro import checkpoint
from repro.runtime import elastic, fault
from repro.runtime.fault import (FaultConfig, FaultTolerantRunner,
                                 StragglerAbort, backoff_delay, supervise)


# ---------------------------------------------------------------------------
# elastic.viable_mesh_shape / viable_schedule_devices edge cases.
# ---------------------------------------------------------------------------

def test_viable_mesh_shape_non_power_of_two_survivors():
    # 7 survivors, TP=2: 3 data ranks round down to the pow2 2.
    assert elastic.viable_mesh_shape(7, model_parallel=2) == (2, 2)
    # 6 survivors, TP=4: one data rank survives.
    assert elastic.viable_mesh_shape(6, model_parallel=4) == (1, 4)


def test_viable_mesh_shape_exactly_minimum():
    assert elastic.viable_mesh_shape(4, model_parallel=4) == (1, 4)
    assert elastic.viable_mesh_shape(8, model_parallel=2,
                                     min_data=4) == (4, 2)


def test_viable_mesh_shape_insufficient():
    assert elastic.viable_mesh_shape(3, model_parallel=4) is None
    assert elastic.viable_mesh_shape(7, model_parallel=2,
                                     min_data=4) is None


def test_viable_schedule_devices_divisibility():
    devs = list(range(8))
    # 8 divides 128: the full mesh survives.
    assert elastic.viable_schedule_devices(devs, 128) == tuple(range(8))
    # 6 survivors, 128 points: 6 and 5 don't divide, 4 does.
    assert elastic.viable_schedule_devices(devs[:6], 128) == (0, 1, 2, 3)
    # prime-sized stack: only 1 device divides -> unsharded fallback.
    assert elastic.viable_schedule_devices(devs[:6], 127) == (0,)


def test_viable_schedule_devices_minimum_and_insufficient():
    devs = list(range(4))
    assert elastic.viable_schedule_devices(devs, 128,
                                           min_devices=4) == (0, 1, 2, 3)
    # 3 survivors can't host a 4-device floor.
    assert elastic.viable_schedule_devices(devs[:3], 128,
                                           min_devices=4) is None
    # indivisible above the floor: no viable mesh either.
    assert elastic.viable_schedule_devices(devs, 126,
                                           min_devices=4) is None
    with pytest.raises(ValueError, match="non-empty schedule axis"):
        elastic.viable_schedule_devices(devs, 0)


def test_rescale_batch_keeps_per_device_constant():
    assert elastic.rescale_batch(64, old_data=8, new_data=6) == 48


# ---------------------------------------------------------------------------
# backoff_delay: exponential, jitter-capped, deterministic.
# ---------------------------------------------------------------------------

def test_backoff_delay_grows_and_caps():
    delays = [backoff_delay(k, base=0.1, cap=5.0, jitter=0.0)
              for k in range(10)]
    assert delays[0] == pytest.approx(0.1)
    assert all(b >= a for a, b in zip(delays, delays[1:]))
    assert delays[-1] == 5.0


def test_backoff_delay_jitter_bounded_and_deterministic():
    for k in range(6):
        raw = min(5.0, 0.1 * 2 ** k)
        d = backoff_delay(k, base=0.1, cap=5.0, jitter=0.25)
        assert raw <= d <= min(5.0, raw * 1.25)
        assert d == backoff_delay(k, base=0.1, cap=5.0, jitter=0.25)


# ---------------------------------------------------------------------------
# FaultTolerantRunner: watchdog + restart-resumes-from-checkpoint.
# ---------------------------------------------------------------------------

def _counter_runner(tmp_path, *, fail_at=None, failures=None,
                    ckpt_every=2, executed=None):
    """A runner whose state counts executed steps; ``fail_at`` raises
    once per entry in ``failures`` (a mutable set) to simulate faults."""
    cfg = FaultConfig(ckpt_dir=str(tmp_path / "ckpt"),
                      ckpt_every=ckpt_every,
                      backoff_base=0.0, backoff_cap=0.0)

    def step_fn(state, batch):
        if failures is not None and batch in failures:
            failures.remove(batch)
            raise RuntimeError(f"node fault at step {batch}")
        if executed is not None:
            executed.append(batch)
        return state + 1, {"step": batch}

    return FaultTolerantRunner(cfg, step_fn=step_fn, batch_fn=lambda s: s,
                               state_template=0)


def test_runner_restart_resumes_from_checkpoint(tmp_path):
    executed = []
    failures = {3}
    make = lambda: _counter_runner(tmp_path, failures=failures,
                                   executed=executed)
    cfg = make().cfg
    state = supervise(make, 6, cfg, sleep=lambda s: None)
    # ckpt at steps 1, 3(never: failed), so restart resumes at step 2:
    # attempt 1 runs 0,1,2 (fault at 3), attempt 2 runs 2..5.
    assert executed == [0, 1, 2, 2, 3, 4, 5]
    # state restored from the step-1 checkpoint counts steps 2..5 only.
    assert state == 2 + 4


def test_supervise_carries_history_and_backs_off(tmp_path):
    sleeps = []
    failures = {3}
    make = lambda: _counter_runner(tmp_path, failures=failures)
    holder = []

    def make_and_keep():
        r = make()
        holder.append(r)
        return r

    cfg = FaultConfig(ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=2,
                      backoff_base=0.5, backoff_cap=2.0,
                      backoff_jitter=0.25)
    supervise(make_and_keep, 6, cfg, sleep=sleeps.append)
    # one restart -> one backoff sleep, the attempt-0 delay
    assert sleeps == [backoff_delay(0, base=0.5, cap=2.0, jitter=0.25)]
    # the failed attempt's steps (0,1,2) survive in the final history
    final = holder[-1].history
    assert [s.step for s in final] == [0, 1, 2, 2, 3, 4, 5]


def test_supervise_gives_up_after_max_restarts(tmp_path):
    cfg = FaultConfig(ckpt_dir=str(tmp_path / "ckpt"), max_restarts=2,
                      backoff_base=0.0, backoff_cap=0.0)

    def make():
        return FaultTolerantRunner(
            cfg, step_fn=lambda s, b: (_ for _ in ()).throw(
                RuntimeError("always down")),
            batch_fn=lambda s: s, state_template=0)

    with pytest.raises(RuntimeError, match="giving up after 2"):
        supervise(make, 4, cfg, sleep=lambda s: None)


def test_straggler_watchdog_triggers():
    cfg = FaultConfig(straggler_factor=3.0, max_stragglers=2)
    runner = FaultTolerantRunner(cfg, step_fn=lambda s, b: (s, {}),
                                 batch_fn=lambda s: s, state_template=0)
    for _ in range(8):
        runner._watch(0.01)          # healthy baseline
    runner._watch(1.0)               # 1st slow step: counted
    with pytest.raises(StragglerAbort, match="2 consecutive"):
        runner._watch(1.0)           # 2nd consecutive: abort


def test_straggler_watchdog_resets_on_fast_step():
    cfg = FaultConfig(straggler_factor=3.0, max_stragglers=2)
    runner = FaultTolerantRunner(cfg, step_fn=lambda s, b: (s, {}),
                                 batch_fn=lambda s: s, state_template=0)
    for _ in range(8):
        runner._watch(0.01)
    runner._watch(1.0)
    runner._watch(0.01)              # recovery resets the streak
    runner._watch(1.0)               # a lone slow step never aborts
    assert runner._slow == 1


# ---------------------------------------------------------------------------
# checkpoint store robustness: corrupt manifests + stale .tmp pruning.
# ---------------------------------------------------------------------------

def test_latest_step_skips_truncated_manifest(tmp_path):
    checkpoint.save(tmp_path, 3, {"w": 1.5})
    checkpoint.save(tmp_path, 7, {"w": 2.5})
    # torn write: manifest exists but is truncated mid-JSON
    (tmp_path / "step_00000007" / "manifest.json").write_text(
        '{"step": 7, "keys": ["w"')
    assert checkpoint.latest_step(tmp_path) == 3
    # unparseable garbage is equally invisible
    (tmp_path / "step_00000007" / "manifest.json").write_bytes(
        b"\xff\xfe not json")
    assert checkpoint.latest_step(tmp_path) == 3
    # and a manifest without a step field does not count either
    (tmp_path / "step_00000007" / "manifest.json").write_text("[1, 2]")
    assert checkpoint.latest_step(tmp_path) == 3


def test_prune_drops_stale_tmp_dirs(tmp_path):
    checkpoint.save(tmp_path, 1, {"w": 1.0})
    stale = tmp_path / "step_00000009.tmp"
    fresh = tmp_path / "step_00000010.tmp"
    stale.mkdir()
    fresh.mkdir()
    old = time.time() - 7200
    os.utime(stale, (old, old))
    checkpoint.prune(tmp_path, keep=3)
    assert not stale.exists(), "stale .tmp (>1h) must be reaped"
    assert fresh.exists(), "in-flight .tmp must survive"
    assert (tmp_path / "step_00000001").exists()


def test_prune_keeps_newest_complete(tmp_path):
    for s in (1, 2, 3, 4):
        checkpoint.save(tmp_path, s, {"w": float(s)})
    checkpoint.prune(tmp_path, keep=2)
    left = sorted(d.name for d in tmp_path.iterdir())
    assert left == ["step_00000003", "step_00000004"]


# ---------------------------------------------------------------------------
# FaultPlan construction-time validation: malformed plans fail fast with
# actionable errors instead of silently never firing mid-sweep.
# ---------------------------------------------------------------------------

def test_fault_plan_valid_plans_construct():
    from repro.runtime.inject import (DeviceLoss, FaultPlan, Preemption,
                                      SimulatedOOM)
    plan = FaultPlan(faults={0: SimulatedOOM(), 3: DeviceLoss(2),
                             7: Preemption()},
                     straggle={1: 0.25, 2: 0.0})
    assert not plan.exhausted
    with pytest.raises(SimulatedOOM):
        plan.at_chunk(0)
    plan.at_chunk(0)                      # fires exactly once
    assert plan.straggle_seconds(1) == 0.25
    assert plan.straggle_seconds(1) == 0.0


def test_fault_plan_rejects_bad_chunk_indices():
    from repro.runtime.inject import FaultPlan, SimulatedOOM
    with pytest.raises(ValueError, match=">= 0"):
        FaultPlan(faults={-1: SimulatedOOM()})
    with pytest.raises(ValueError, match="int"):
        FaultPlan(faults={"2": SimulatedOOM()})
    with pytest.raises(ValueError, match="int"):
        FaultPlan(faults={True: SimulatedOOM()})
    with pytest.raises(ValueError, match=">= 0"):
        FaultPlan(straggle={-3: 1.0})


def test_fault_plan_rejects_unknown_fault_kinds():
    from repro.runtime.inject import FaultPlan
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan(faults={0: RuntimeError("not a simulated fault")})
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan(faults={0: "oom"})


def test_fault_plan_rejects_duplicate_fire_points():
    from repro.runtime.inject import DeviceLoss, FaultPlan, SimulatedOOM
    shared = SimulatedOOM()
    with pytest.raises(ValueError, match="duplicate fire point"):
        FaultPlan(faults={0: shared, 2: shared})
    # distinct instances of the same kind are fine
    FaultPlan(faults={0: SimulatedOOM(), 2: SimulatedOOM()})
    FaultPlan(faults={0: DeviceLoss(1), 1: DeviceLoss(1)})


def test_fault_plan_rejects_bad_straggle_seconds():
    from repro.runtime.inject import FaultPlan
    with pytest.raises(ValueError, match="finite"):
        FaultPlan(straggle={0: float("inf")})
    with pytest.raises(ValueError, match="finite"):
        FaultPlan(straggle={0: float("nan")})
    with pytest.raises(ValueError, match="finite"):
        FaultPlan(straggle={0: -0.5})
