"""Barrier simulator: paper-claim validation + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import barrier, barrier_sim, fiveg, workloads
from repro.core.topology import DEFAULT, TeraPoolConfig

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Schedule structure.
# ---------------------------------------------------------------------------

def test_schedule_structure():
    s = barrier.kary_tree(32)
    assert s.n_levels == 2
    assert [l.group_size for l in s.levels] == [32, 32]
    s = barrier.kary_tree(8)   # log8(1024) not integer -> first level 2
    assert [l.group_size for l in s.levels] == [2, 8, 8, 8]
    assert np.prod([l.group_size for l in s.levels]) == 1024
    c = barrier.central_counter()
    assert c.n_levels == 1 and c.levels[0].group_size == 1024


@given(st.sampled_from([2, 4, 8, 16, 32, 64, 128, 256, 512, 1024]),
       st.sampled_from([64, 128, 256, 512, 1024]))
def test_schedule_products_cover_all_pes(radix, n_pes):
    if radix > n_pes:
        return
    s = barrier.kary_tree(radix, n_pes=n_pes)
    assert np.prod([l.group_size for l in s.levels]) == n_pes
    # spans increase monotonically and latencies are non-decreasing
    spans = [l.span for l in s.levels]
    assert spans == sorted(spans)
    lats = [l.latency for l in s.levels]
    assert lats == sorted(lats)


def test_invalid_radix_rejected():
    with pytest.raises(ValueError):
        barrier.kary_tree(3)
    with pytest.raises(ValueError):
        barrier.kary_tree(2048)


# ---------------------------------------------------------------------------
# Simulator invariants (property-based).
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 16), st.sampled_from([2, 16, 128, 1024]),
       st.floats(0, 4096))
def test_exit_after_every_arrival(seed, radix, max_delay):
    arr = jax.random.uniform(jax.random.PRNGKey(seed), (1024,),
                             minval=0.0, maxval=max(max_delay, 1e-3))
    res = barrier_sim.simulate(arr, barrier.kary_tree(radix))
    assert float(res.exit_time) > float(res.last_arrival)
    assert float(res.mean_residency) > 0


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 16), st.sampled_from([4, 64, 1024]))
def test_shift_equivariance(seed, radix):
    """Shifting all arrivals by T shifts the exit by exactly T."""
    arr = jax.random.uniform(jax.random.PRNGKey(seed), (1024,),
                             minval=0.0, maxval=500.0)
    s = barrier.kary_tree(radix)
    r0 = barrier_sim.simulate(arr, s)
    r1 = barrier_sim.simulate(arr + 1000.0, s)
    np.testing.assert_allclose(float(r1.exit_time),
                               float(r0.exit_time) + 1000.0, rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 16))
def test_monotone_in_arrivals(seed):
    """Delaying one PE can never make the barrier finish earlier."""
    key = jax.random.PRNGKey(seed)
    arr = jax.random.uniform(key, (1024,), minval=0.0, maxval=300.0)
    s = barrier.kary_tree(16)
    base = float(barrier_sim.simulate(arr, s).exit_time)
    arr2 = arr.at[7].add(500.0)
    later = float(barrier_sim.simulate(arr2, s).exit_time)
    assert later >= base - 1e-4


def test_serialization_lower_bound():
    """Zero-delay central counter must serialize >= N_PE bank cycles."""
    res = barrier_sim.simulate(jnp.zeros(1024), barrier.central_counter())
    assert float(res.span_cycles) >= 1024


# ---------------------------------------------------------------------------
# Paper claims (EXPERIMENTS.md §Repro C1-C3).
# ---------------------------------------------------------------------------

def _span(radix, delay):
    s = barrier.kary_tree(radix)
    return float(barrier_sim.mean_span_cycles(KEY, s, delay, n_trials=8))


def test_c1_scoop_at_zero_delay():
    spans = {k: _span(k, 0.0) for k in (2, 16, 32, 512, 1024)}
    # central counter is the worst, mid radices the best
    assert spans[1024] == max(spans.values())
    assert min(spans, key=spans.get) in (16, 32)
    assert spans[2] > spans[16]          # log tree pays its level count


def test_c2_staircase_at_large_delay():
    spans = {k: _span(k, 2048.0) for k in (2, 16, 256, 1024)}
    # arrivals scattered -> central counter becomes the best
    assert spans[1024] == min(spans.values())
    assert spans[2] == max(spans.values())


def test_c3_sfr_for_10pct_overhead():
    """<10% overhead requires SFR between ~2k and ~10k cycles depending
    on arrival scatter (paper Fig. 4b)."""
    for delay, lo, hi in [(256.0, 500, 4000), (2048.0, 4000, 16000)]:
        best = None
        for radix in (16, 32, 64, 1024):
            s = barrier.kary_tree(radix)
            arr = barrier_sim.uniform_arrivals(KEY, delay, 1024, 8)
            res = barrier_sim.simulate(arr, s)
            cost = float(jnp.mean(res.mean_residency))
            best = cost if best is None else min(best, cost)
        sfr_needed = best * 9.0          # overhead <10% -> SFR >= 9x cost
        assert lo < sfr_needed < hi, (delay, sfr_needed)


# ---------------------------------------------------------------------------
# Kernel workloads (C5 qualitative ordering) + 5G app (C4).
# ---------------------------------------------------------------------------

def test_kernel_cdf_shapes():
    suite = workloads.benchmark_suite()
    gaps = {}
    for kernel, dims in suite.items():
        label, fn = max(dims.items())
        arr = fn(KEY)
        gaps[kernel] = float(workloads.cdf_first_last_gap(arr))
    # local-access kernels finish together; reduction scatters dotp
    assert gaps["axpy"] < gaps["dotp"]
    assert gaps["dotp"] > 900             # serialized atomic reduction
    assert gaps["conv2d"] > gaps["axpy"]  # border imbalance


def test_c4_5g_application():
    app = fiveg.FiveGConfig(n_rx=16, ffts_per_round=1)
    res = fiveg.compare_barriers(KEY, app, radix=32)
    speedup = float(res["speedup_partial"])
    assert 1.4 <= speedup <= 1.8, speedup          # paper: 1.6x

    app4 = fiveg.FiveGConfig(n_rx=64, ffts_per_round=4)
    res4 = fiveg.compare_barriers(KEY, app4, radix=32)
    frac = float(res4["partial"].sync_fraction)
    assert frac <= 0.062 + 0.01, frac              # paper: 6.2%
    assert float(res4["speedup_partial"]) > 1.0
    # speed-up shrinks as more FFTs amortize each barrier (paper)
    assert float(res4["speedup_partial"]) < speedup
    # parallel efficiency vs serial Snitch
    assert float(res4["partial"].speedup_serial) > 500
