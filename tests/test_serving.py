"""Tuning-serving daemon: batched dispatch is bit-for-bit equal to
unbatched sweeps, dedup is idempotent, admission control rejects with
retry-after, deadlines degrade down the labeled three-tier ladder, the
circuit breaker trips and recovers through probes, DeviceLoss /
straggler faults mid-batch lose no request, shutdown drains or
checkpoints the queue, and the 5G client mode resolves its schedules
through the server exactly as the inline tuner would."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fiveg, sweep, tuning, workloads
from repro.core.fiveg import FiveGConfig
from repro.core.placement import STRATEGIES
from repro.core.topology import TeraPoolConfig
from repro.runtime import (DeviceLoss, FaultPlan, ResilienceConfig,
                           SimulatedOOM, schedule_cache)
from repro.runtime.serving import (BATCHED, CACHE_HIT, DEGRADED,
                                   ServerClosed, ServerConfig,
                                   ServerOverloaded, TIER_CACHE,
                                   TIER_EXACT, TIER_FALLBACK,
                                   TuneRequest, TuneResponse,
                                   TuningServer, fallback_uniform)

KEY = jax.random.PRNGKey(7)
CFG = TeraPoolConfig(n_pes=64)


def _cfg(**kw):
    kw.setdefault("batch_window", 0.01)
    return ServerConfig(**kw)


def _trace(i, trials=4, scale=300.0):
    return np.asarray(
        scale * jax.random.uniform(jax.random.fold_in(KEY, i),
                                   (trials, 64)), np.float32)


def _nosleep(_):
    pass


@pytest.fixture
def cache_env(tmp_path, monkeypatch):
    monkeypatch.setenv(schedule_cache.CACHE_ENV, str(tmp_path / "cache"))
    schedule_cache.reset_stats()
    yield tmp_path / "cache"
    schedule_cache.reset_stats()


# ---------------------------------------------------------------------------
# Request validation and the closed-form fallback tier.
# ---------------------------------------------------------------------------

def test_request_validation():
    srv = TuningServer(_cfg(), start=False)
    with pytest.raises(ValueError, match="exactly one"):
        srv.submit(TuneRequest())
    with pytest.raises(ValueError, match="exactly one"):
        srv.submit(TuneRequest(kernel="dotp_1Mi", arrivals=_trace(0)))
    with pytest.raises(ValueError, match="unknown kernel"):
        srv.submit(TuneRequest(kernel="nonesuch", cfg=CFG))
    with pytest.raises(ValueError, match="unknown objective"):
        srv.submit(TuneRequest(kernel="dotp_1Mi", cfg=CFG,
                               objective="watts"))
    with pytest.raises(ValueError, match="arrivals must be"):
        srv.submit(TuneRequest(arrivals=np.zeros((2, 2, 2), np.float32)))
    with pytest.raises(ValueError, match="n_pes=32"):
        srv.submit(TuneRequest(arrivals=_trace(0), n_pes=32))
    srv.close()


def test_fallback_uniform_objectives():
    points = {obj: fallback_uniform(64, CFG, obj)
              for obj in ("cycles", "energy", "edp", "pareto")}
    for sched, sp, en in points.values():
        assert sched.n_pes == 64 and sp > 0 and en > 0
    # the cycles pick minimizes the analytic span over every radix
    from repro.core import barrier
    from repro.runtime.serving import _analytic_span
    spans = [_analytic_span(barrier.kary_tree(k, 64, CFG), CFG)
             for k in barrier.all_radices(64, CFG)]
    assert points["cycles"][1] == min(spans)
    with pytest.raises(ValueError, match="unknown objective"):
        fallback_uniform(64, CFG, "watts")
    # prime N: the central counter is the only uniform tree
    sched, _, _ = fallback_uniform(7, TeraPoolConfig(n_pes=7), "cycles")
    assert sched.sizes == (7,)


def test_knee_point():
    mk = lambda sp, en: tuning.ParetoPoint(None, None, "p", sp, en)
    front = [mk(10.0, 100.0), mk(12.0, 40.0), mk(30.0, 30.0)]
    # (12, 40) is closest to the normalized utopia corner
    assert tuning.knee_point(front).mean_span == 12.0
    assert tuning.knee_point([mk(5.0, 5.0)]).mean_span == 5.0
    with pytest.raises(ValueError):
        tuning.knee_point([])


def test_split_kernels_bit_for_bit():
    scheds = tuning.all_schedules(64, CFG)
    stack = np.stack([_trace(0), _trace(1)])
    batched = sweep.sweep_arrivals(stack, scheds, CFG, kernels=("a", "b"))
    parts = sweep.split_kernels(batched)
    assert [p.kernels for p in parts] == [("a",), ("b",)]
    for j, part in enumerate(parts):
        solo = sweep.sweep_arrivals(stack[j], scheds, CFG,
                                    kernels=(batched.kernels[j],))
        for field in ("exit_time", "span_cycles", "energy",
                      "mean_residency"):
            np.testing.assert_array_equal(
                np.asarray(getattr(part, field)),
                np.asarray(getattr(solo, field)), err_msg=field)


# ---------------------------------------------------------------------------
# The happy path: exact batched answers, memoized second hits.
# ---------------------------------------------------------------------------

def test_exact_then_cache_hit():
    with TuningServer(_cfg()) as srv:
        req = TuneRequest(kernel="dotp_1Mi", n_pes=64, cfg=CFG)
        r1 = srv.tune(req, timeout=300)
        assert (r1.provenance, r1.tier) == (BATCHED, TIER_EXACT)
        assert r1.schedule is not None and r1.mean_span > 0
        assert r1.result is not None and r1.batch_size == 1
        r2 = srv.tune(TuneRequest(kernel="dotp_1Mi", n_pes=64, cfg=CFG),
                      timeout=60)
        assert (r2.provenance, r2.tier) == (CACHE_HIT, TIER_CACHE)
        assert r2.name == r1.name
        assert srv.stats.batches == 1 and srv.stats.cache_hits == 1


def test_batched_equals_unbatched_bit_for_bit():
    """Three compatible trace requests fuse into ONE dispatch whose
    per-request slices — and winners — are bit-for-bit what unbatched
    sweep_arrivals / tune_for_arrivals produce."""
    traces = [_trace(i) for i in range(3)]
    srv = TuningServer(_cfg(batch_window=0.05), start=False)
    tickets = [srv.submit(TuneRequest(arrivals=t)) for t in traces]
    srv.start()
    resps = [t.result(timeout=300) for t in tickets]
    srv.close()
    scheds = tuning.all_schedules(64, CFG, prune="none")
    for trace, resp in zip(traces, resps):
        assert (resp.provenance, resp.tier) == (BATCHED, TIER_EXACT)
        assert resp.batch_size == 3
        base = sweep.sweep_arrivals(trace, scheds, CFG)
        for field in ("exit_time", "span_cycles", "energy"):
            np.testing.assert_array_equal(
                np.asarray(getattr(resp.result, field)),
                np.asarray(getattr(base, field)), err_msg=field)
        want_sched, want_plc, want_span = tuning.tune_for_arrivals(
            trace, CFG, prune="none")
        assert resp.schedule == want_sched and resp.placement == want_plc
        assert resp.mean_span == want_span
    assert srv.stats.batches == 1 and srv.stats.batch_requests == 3
    assert srv.stats.batch_efficiency == 3.0


def test_mixed_objectives_share_one_dispatch():
    trace = _trace(9)
    srv = TuningServer(_cfg(batch_window=0.05), start=False)
    tickets = {obj: srv.submit(TuneRequest(arrivals=trace, objective=obj))
               for obj in ("cycles", "energy", "pareto")}
    srv.start()
    resps = {obj: t.result(timeout=300) for obj, t in tickets.items()}
    srv.close()
    assert srv.stats.batches == 1
    scheds = tuning.all_schedules(64, CFG, prune="none")
    res = sweep.sweep_arrivals(trace, scheds, CFG)
    sp = np.asarray(res.mean_span)[:, 0]
    en = np.asarray(res.mean_energy)[:, 0]
    assert resps["cycles"].name == res.names[int(np.argmin(sp))]
    assert resps["energy"].name == res.names[int(np.argmin(en))]
    knee = tuning.knee_point(tuning.pareto_front(res))
    assert resps["pareto"].name == knee.name
    # the knee never spends more energy than the pure-cycles winner
    assert resps["pareto"].mean_energy <= resps["cycles"].mean_energy


def test_dedup_is_idempotent():
    srv = TuningServer(_cfg(), start=False)
    req = lambda: TuneRequest(kernel="conv2d_256x256", n_pes=64, cfg=CFG)
    t1, t2 = srv.submit(req()), srv.submit(req())
    assert t1 is not t2
    srv.start()
    r1, r2 = t1.result(timeout=300), t2.result(timeout=300)
    srv.close()
    assert r1 is r2                       # one pending, one shared answer
    assert r1.provenance == BATCHED
    assert srv.stats.deduped == 1 and srv.stats.batches == 1


# ---------------------------------------------------------------------------
# Admission control, deadlines, the degradation ladder.
# ---------------------------------------------------------------------------

def test_queue_overflow_rejects_with_retry_after():
    srv = TuningServer(_cfg(queue_depth=2), start=False)
    t1 = srv.submit(TuneRequest(arrivals=_trace(0)))
    srv.submit(TuneRequest(arrivals=_trace(1)))
    with pytest.raises(ServerOverloaded) as exc:
        srv.submit(TuneRequest(arrivals=_trace(2)))
    assert exc.value.retry_after > 0
    assert srv.stats.rejected == 1 and srv.stats.accepted == 2
    # the accepted requests are NOT lost: they drain exactly
    srv.start()
    assert t1.result(timeout=300).provenance == BATCHED
    srv.close()


def test_expired_deadline_degrades_to_fallback():
    with TuningServer(_cfg()) as srv:
        resp = srv.tune(TuneRequest(arrivals=_trace(3), deadline=0.0),
                        timeout=60)
    assert (resp.provenance, resp.tier) == (DEGRADED, TIER_FALLBACK)
    assert "deadline" in resp.detail
    want, sp, en = fallback_uniform(64, CFG, "cycles")
    assert resp.schedule == want
    assert (resp.mean_span, resp.mean_energy) == (sp, en)
    assert srv.stats.degraded == 1 and srv.stats.batches == 0


def test_degrade_ladder_prefers_cache_over_fallback(cache_env):
    # warm the persistent cache with an exact answer...
    with TuningServer(_cfg()) as srv:
        exact = srv.tune(TuneRequest(kernel="dotp_1Mi", n_pes=64, cfg=CFG),
                         timeout=300)
    # ...then a FRESH server (cold memo) degrades the same request into
    # the cache tier, not the closed-form tier.
    srv2 = TuningServer(_cfg(), start=False)
    pending = srv2._normalize(
        TuneRequest(kernel="dotp_1Mi", n_pes=64, cfg=CFG))
    srv2._degrade(pending, "test-forced degrade")
    resp = pending.tickets[0].result(timeout=10)
    srv2.close()
    assert (resp.provenance, resp.tier) == (DEGRADED, TIER_CACHE)
    assert resp.name == exact.name
    assert "test-forced degrade" in resp.detail


# ---------------------------------------------------------------------------
# Faults: retry with backoff, circuit breaker, resilient dispatch.
# ---------------------------------------------------------------------------

def test_batch_retry_after_transient_fault():
    plan = FaultPlan(faults={0: SimulatedOOM()})
    cfg = _cfg(max_batch_retries=2, backoff_base=0.0, backoff_cap=0.0)
    with TuningServer(cfg, fault_plan=plan, sleep=_nosleep) as srv:
        resp = srv.tune(TuneRequest(arrivals=_trace(4)), timeout=300)
    assert resp.provenance == BATCHED      # the retry succeeded
    assert plan.exhausted
    assert srv.stats.faults.get("SimulatedOOM") == 1
    assert srv.stats.batch_failures == 1


def test_circuit_breaker_trips_then_probes_closed():
    plan = FaultPlan(faults={0: SimulatedOOM(), 1: SimulatedOOM()})
    cfg = _cfg(max_batch_retries=0, breaker_threshold=1,
               breaker_probe_after=0.0, backoff_base=0.0, backoff_cap=0.0)
    with TuningServer(cfg, fault_plan=plan, sleep=_nosleep) as srv:
        r1 = srv.tune(TuneRequest(arrivals=_trace(5)), timeout=300)
        assert r1.provenance == DEGRADED and r1.tier == TIER_FALLBACK
        assert srv.breaker_state != "closed"   # tripped (probe-ready)
        # probe batch: fails again -> still degraded, breaker re-opens
        r2 = srv.tune(TuneRequest(arrivals=_trace(6)), timeout=300)
        assert r2.provenance == DEGRADED
        # next probe succeeds -> breaker closes, exact service resumes
        r3 = srv.tune(TuneRequest(arrivals=_trace(7)), timeout=300)
        assert r3.provenance == BATCHED
        assert srv.breaker_state == "closed"
    assert srv.stats.faults.get("SimulatedOOM") == 2


def test_deviceloss_and_straggler_midbatch_no_request_lost(tmp_path):
    """The acceptance scenario: DeviceLoss mid-batch (the resilient
    layer remeshes onto the survivors and resumes from the chunk
    store) plus an injected straggler abort — every request still
    answered EXACTLY, bit-for-bit with the plain unbatched sweep."""
    rcfg = ResilienceConfig(ckpt_dir=str(tmp_path / "chunks"),
                            trial_chunk=1, backoff_base=0.0,
                            backoff_cap=0.0, straggler_factor=2.0,
                            straggler_floor=0.0)
    # 8 trials / trial_chunk=1 -> 8 chunks: DeviceLoss at chunk 1, a
    # 1e6 s straggler at chunk 5 (the watchdog needs >= 3 baseline
    # chunk durations before it can call anything a straggler).
    plan = FaultPlan(faults={1: DeviceLoss(1)}, straggle={5: 1e6})
    cfg = _cfg(batch_window=0.05, max_batch_retries=3, backoff_base=0.0,
               backoff_cap=0.0, resilience=rcfg,
               ckpt_dir=str(tmp_path / "srv"))
    traces = [_trace(10, trials=8), _trace(11, trials=8)]
    srv = TuningServer(cfg, fault_plan=plan, sleep=_nosleep, start=False)
    tickets = [srv.submit(TuneRequest(arrivals=t)) for t in traces]
    srv.start()
    resps = [t.result(timeout=600) for t in tickets]
    srv.close()
    scheds = tuning.all_schedules(64, CFG, prune="none")
    for trace, resp in zip(traces, resps):
        assert (resp.provenance, resp.tier) == (BATCHED, TIER_EXACT)
        base = sweep.sweep_arrivals(trace, scheds, CFG)
        np.testing.assert_array_equal(
            np.asarray(resp.result.span_cycles),
            np.asarray(base.span_cycles))
    assert srv.stats.faults.get("DeviceLoss", 0) >= 1
    assert srv.stats.faults.get("StragglerAbort", 0) >= 1
    assert plan.exhausted


# ---------------------------------------------------------------------------
# Shutdown: drain and checkpoint/restore.
# ---------------------------------------------------------------------------

def test_close_drains_pending_requests():
    srv = TuningServer(_cfg(), start=False)
    tickets = [srv.submit(TuneRequest(arrivals=_trace(i)))
               for i in range(12, 15)]
    srv.close(drain=True)                  # answers everything first
    for t in tickets:
        assert t.done()
        assert t.result().provenance == BATCHED
    with pytest.raises(ServerClosed):
        srv.submit(TuneRequest(arrivals=_trace(15)))


def test_shutdown_checkpoints_queue_and_restart_restores(tmp_path):
    root = str(tmp_path / "srv")
    srv = TuningServer(_cfg(ckpt_dir=root), start=False)
    t1 = srv.submit(TuneRequest(kernel="dotp_1Mi", n_pes=64, cfg=CFG))
    t2 = srv.submit(TuneRequest(arrivals=_trace(16), objective="energy"))
    srv.close(drain=False)
    # parked tickets were answered through the ladder, not dropped
    for t in (t1, t2):
        resp = t.result(timeout=10)
        assert resp.provenance == DEGRADED and resp.tier == TIER_FALLBACK
        assert "checkpointed" in resp.detail
    assert (tmp_path / "srv" / "queue.json").exists()
    # a restarted server re-enqueues and answers them exactly
    srv2 = TuningServer(_cfg(ckpt_dir=root), start=False)
    assert srv2.stats.restored == 2
    assert not (tmp_path / "srv" / "queue.json").exists()
    srv2.start()
    srv2.flush(timeout=600)
    # the replay warmed the server cache: the same request is now a hit
    r = srv2.tune(TuneRequest(kernel="dotp_1Mi", n_pes=64, cfg=CFG),
                  timeout=60)
    srv2.close()
    assert (r.provenance, r.tier) == (CACHE_HIT, TIER_CACHE)
    assert srv2.stats.batches >= 1


# ---------------------------------------------------------------------------
# The 5G client mode and sync="pareto".
# ---------------------------------------------------------------------------

def test_fiveg_client_mode_matches_inline_tuning():
    app = FiveGConfig()
    fiveg._workload_schedules.cache_clear()
    want = fiveg._workload_schedules(app, CFG)
    with TuningServer(_cfg(batch_window=0.05)) as srv:
        with fiveg.tuning_server(srv):
            got = fiveg._served_schedules(app, CFG, "cycles")
        # stage + global coalesced into ONE batched dispatch
        assert srv.stats.batches == 1 and srv.stats.batch_requests == 2
    assert [s.sizes for s in (got[0], got[2])] == \
        [s.sizes for s in (want[0], want[2])]
    assert (got[1], got[3]) == (want[1], want[3])


def test_fiveg_client_mode_simulates_identically():
    app = FiveGConfig()
    key = jax.random.PRNGKey(3)
    base = fiveg.simulate_app(key, app, sync="workload", cfg=CFG)
    with TuningServer(_cfg(batch_window=0.05)) as srv:
        with fiveg.tuning_server(srv):
            served = fiveg.simulate_app(key, app, sync="workload", cfg=CFG)
    assert served.stage_schedule == base.stage_schedule
    assert served.global_schedule == base.global_schedule
    np.testing.assert_array_equal(np.asarray(served.total_cycles),
                                  np.asarray(base.total_cycles))
    np.testing.assert_array_equal(np.asarray(served.sync_energy),
                                  np.asarray(base.sync_energy))


def test_sync_pareto_picks_the_knee():
    app = FiveGConfig()
    fiveg._pareto_schedules.cache_clear()
    res = fiveg.simulate_app(jax.random.PRNGKey(4), app, sync="pareto",
                             cfg=CFG)
    assert float(res.total_cycles) > 0
    # the stage pick IS the knee of the 2-D front on the stage model
    stage_arr, _ = fiveg._epoch_arrival_models(app, CFG)
    scheds, placs = tuning._cross_placements(
        tuning.all_schedules(64, CFG, prune="none"), STRATEGIES, CFG)
    grid = sweep.sweep_arrivals(stage_arr, scheds, CFG, placements=placs)
    knee = tuning.knee_point(tuning.pareto_front(grid))
    assert res.stage_schedule == knee.name
    # the knee is never more energy-hungry than the best-by-cycles end
    front = tuning.pareto_front(grid)
    assert knee.mean_energy <= front[0].mean_energy


def test_circuit_breaker_half_open_probe_under_concurrent_submits():
    """The half-open race: while the breaker is probe-ready, several
    clients submit CONCURRENTLY.  max_batch=1 serializes them through
    the single worker, so exactly ONE request becomes the (failing)
    probe batch and is degraded; the probe's failure re-opens then
    re-probes, the next becomes the successful probe, and every later
    request is served exactly.  No double-trip (failures never exceed
    the threshold bookkeeping), no wedged thread (every ticket
    resolves), breaker closed at the end."""
    import threading
    plan = FaultPlan(faults={0: SimulatedOOM(), 1: SimulatedOOM()})
    cfg = _cfg(max_batch_retries=0, breaker_threshold=1,
               breaker_probe_after=0.0, backoff_base=0.0,
               backoff_cap=0.0, max_batch=1)
    with TuningServer(cfg, fault_plan=plan, sleep=_nosleep) as srv:
        # Trip the breaker (fault 0), leaving it probe-ready
        # (probe_after=0.0 -> immediately half-open).
        r0 = srv.tune(TuneRequest(arrivals=_trace(20)), timeout=300)
        assert r0.provenance == DEGRADED and r0.tier == TIER_FALLBACK
        assert srv.breaker_state != "closed"

        # 4 concurrent submits race into the half-open breaker.
        resps = [None] * 4
        def client(j):
            resps[j] = srv.tune(TuneRequest(arrivals=_trace(21 + j)),
                                timeout=300)
        threads = [threading.Thread(target=client, args=(j,))
                   for j in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)
            assert not t.is_alive(), "wedged client thread"

        # Exactly one of the racers was the failing probe (fault 1);
        # the rest were served exactly once the breaker closed.
        provs = sorted(r.provenance for r in resps)
        assert provs == [BATCHED, BATCHED, BATCHED, DEGRADED], provs
        assert all(r.ok for r in resps if r.provenance == BATCHED)
        assert srv.breaker_state == "closed"
        assert srv._breaker_failures == 0
    assert srv.stats.faults.get("SimulatedOOM") == 2
