"""Substrate tests: optimizer, quantization, data, checkpoint, fault
runtime, collectives."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import checkpoint, optim
from repro.data import DataConfig, SyntheticLM
from repro.optim import quant
from repro.runtime import (FaultConfig, FaultTolerantRunner, StragglerAbort,
                           elastic)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Quantization.
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.sampled_from([(64,), (3, 128), (2, 5, 256), (1,), (7, 3)]),
       st.floats(1e-4, 1e3))
def test_quant_roundtrip_error_bound(shape, scale):
    x = jnp.asarray(np.random.default_rng(0).standard_normal(shape)
                    * scale, jnp.float32)
    q = quant.quantize(x)
    back = quant.dequantize(q)
    amax = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True)
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert (err <= amax / 127.0 * 0.501 + 1e-9).all()


def test_adamw_converges_quadratic():
    """Full int8+factored config still optimizes a quadratic."""
    cfg = optim.OptConfig(lr=0.05, weight_decay=0.0, warmup_steps=1,
                          total_steps=200, moment_dtype="int8",
                          factored_second_moment=True)
    target = jnp.asarray(np.random.default_rng(1).standard_normal((8, 16)),
                         jnp.float32)
    params = {"w": jnp.zeros((8, 16))}
    state = optim.init(params, cfg)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda q: jnp.mean((q["w"] - target) ** 2))(p)
        return optim.update(g, s, p, cfg)

    for _ in range(200):
        params, state = step(params, state)
    assert float(jnp.mean((params["w"] - target) ** 2)) < 1e-2


def test_moment_dtypes_agree():
    """int8 moments track f32 moments to within quantization error."""
    target = jnp.ones((4, 64)) * 3
    outs = {}
    for md in ("float32", "int8"):
        cfg = optim.OptConfig(lr=0.02, weight_decay=0.0, warmup_steps=1,
                              moment_dtype=md)
        params = {"w": jnp.zeros((4, 64))}
        state = optim.init(params, cfg)
        for _ in range(50):
            g = jax.grad(lambda p: jnp.mean((p["w"] - target) ** 2))(params)
            params, state = optim.update(g, state, params, cfg)
        outs[md] = params["w"]
    np.testing.assert_allclose(outs["float32"], outs["int8"],
                               rtol=0.15, atol=0.05)


def test_schedule_warmup_cosine():
    cfg = optim.OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(optim.schedule(cfg, jnp.asarray(0))) < 0.11
    assert float(optim.schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(optim.schedule(cfg, jnp.asarray(100))) == pytest.approx(
        0.1, abs=1e-3)


# ---------------------------------------------------------------------------
# Data pipeline.
# ---------------------------------------------------------------------------

def test_data_deterministic_and_sharded():
    cfg = DataConfig(seed=3, seq_len=64, global_batch=8, vocab_size=1000)
    s = SyntheticLM(cfg)
    a = s.batch(5)
    b = s.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # host sharding partitions the global batch deterministically
    h0 = s.batch(5, host_id=0, host_count=2)
    h1 = s.batch(5, host_id=1, host_count=2)
    assert h0["tokens"].shape == (4, 64)
    assert not np.array_equal(h0["tokens"], h1["tokens"])
    # next-token alignment
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["targets"][:, :-1])


def test_data_induction_signal():
    cfg = DataConfig(seed=0, seq_len=256, global_batch=4, copy_period=64)
    b = SyntheticLM(cfg).batch(0)
    t = b["tokens"]
    # second half of each period copies the first half
    assert (t[:, 96] == t[:, 64]).all()


# ---------------------------------------------------------------------------
# Checkpointing.
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_prune(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    for step in (1, 2, 3, 4):
        checkpoint.save(tmp_path, step, tree)
    assert checkpoint.latest_step(tmp_path) == 4
    template = jax.tree.map(jnp.zeros_like, tree)
    restored, manifest = checkpoint.restore(tmp_path, template)
    np.testing.assert_array_equal(restored["a"], tree["a"])
    assert restored["b"]["c"].dtype == jnp.bfloat16
    checkpoint.prune(tmp_path, keep=2)
    assert checkpoint.latest_step(tmp_path) == 4
    assert len(list(tmp_path.glob("step_*"))) == 2


def test_checkpoint_atomicity(tmp_path):
    """A missing manifest (simulated crash) is never considered latest."""
    tree = {"a": jnp.ones((2,))}
    checkpoint.save(tmp_path, 1, tree)
    # fake a torn write
    torn = tmp_path / "step_00000009"
    torn.mkdir()
    (torn / "host_0000.npz").write_bytes(b"garbage")
    assert checkpoint.latest_step(tmp_path) == 1


# ---------------------------------------------------------------------------
# Fault-tolerant runtime.
# ---------------------------------------------------------------------------

def _runner(tmp_path, fail_at=None, slow_at=(), state0=0.0):
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if fail_at is not None and calls["n"] == fail_at:
            raise RuntimeError("injected node failure")
        import time
        if calls["n"] in slow_at:
            time.sleep(0.05)
        return state + batch, {"loss": float(state)}

    cfg = FaultConfig(ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=3,
                      straggler_factor=5.0, max_stragglers=3,
                      max_restarts=2)
    return FaultTolerantRunner(cfg, step_fn=step_fn,
                               batch_fn=lambda step: 1.0,
                               state_template=jnp.asarray(state0))


def test_runner_checkpoints_and_resumes(tmp_path):
    r = _runner(tmp_path)
    final = r.run(7)
    assert float(final) == 7.0
    assert checkpoint.latest_step(tmp_path / "ckpt") == 6
    # resume continues from step 7, not from scratch
    r2 = _runner(tmp_path)
    assert r2.resume_step() == 7
    final2 = r2.run(10)
    assert float(final2) == 10.0


def test_supervisor_restarts_after_failure(tmp_path):
    from repro.runtime import supervise
    attempts = {"n": 0}

    def make():
        attempts["n"] += 1
        return _runner(tmp_path, fail_at=5 if attempts["n"] == 1 else None)

    cfg = FaultConfig(ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=3,
                      max_restarts=2)
    final = supervise(make, 8, cfg)
    assert float(final) == 8.0
    assert attempts["n"] == 2


def test_straggler_detection():
    import time
    durations = [0.001] * 10
    r = FaultTolerantRunner(
        FaultConfig(straggler_factor=3.0, max_stragglers=2,
                    ckpt_dir="/tmp/unused_ckpt", ckpt_every=10 ** 9),
        step_fn=lambda s, b: (s, {}), batch_fn=lambda s: 0,
        state_template=0)
    for d in durations:
        r._watch(d)
    with pytest.raises(StragglerAbort):
        r._watch(1.0)
        r._watch(1.0)


def test_elastic_mesh_shapes():
    assert elastic.viable_mesh_shape(256, model_parallel=16) == (16, 16)
    assert elastic.viable_mesh_shape(240, model_parallel=16) == (8, 16)
    assert elastic.viable_mesh_shape(8, model_parallel=16) is None
    assert elastic.rescale_batch(256, 16, 8) == 128
