"""Resilient sweep serving: kill-at-any-chunk-boundary resume is
bit-for-bit invisible (sweep_schedules AND sweep_arrivals, including
the multi-device shard_map path with a shrunken mesh, run in an
8-device subprocess), deterministic fault injection fires exactly
once, the supervisor retries with capped backoff and elastic
re-sharding, the straggler watchdog reschedules slow chunks, and the
persistent schedule cache serves process-level hits while rejecting
corrupt entries."""
import json
import os
import subprocess
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sweep, tuning
from repro.runtime import (DeviceLoss, FaultPlan, Preemption,
                           ResilienceConfig, SimulatedFault, SimulatedOOM,
                           StragglerAbort, resilient_sweep_arrivals,
                           resilient_sweep_schedules,
                           resilient_sweep_workloads,
                           resilient_tune_barrier, schedule_cache)

KEY = jax.random.PRNGKey(0)
REPO = Path(__file__).resolve().parent.parent
DELAYS = (0.0, 512.0)
N_TRIALS = 8


def _rcfg(tmp_path, **kw):
    kw.setdefault("trial_chunk", 2)
    kw.setdefault("backoff_base", 0.0)
    kw.setdefault("backoff_cap", 0.0)
    return ResilienceConfig(ckpt_dir=str(tmp_path / "chunks"), **kw)


def _nosleep(_):
    pass


def _assert_same(got, want):
    for name, a, b in zip(got._fields, got, want):
        if isinstance(a, (jnp.ndarray, np.ndarray)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)
        else:
            assert a == b, name


# ---------------------------------------------------------------------------
# FaultPlan: deterministic, fire-once.
# ---------------------------------------------------------------------------

def test_fault_plan_fires_once():
    plan = FaultPlan(faults={1: SimulatedOOM()}, straggle={2: 5.0})
    plan.at_chunk(0)                       # no fault planned here
    with pytest.raises(SimulatedOOM):
        plan.at_chunk(1)
    plan.at_chunk(1)                       # consumed: retry passes
    assert plan.straggle_seconds(2) == 5.0
    assert plan.straggle_seconds(2) == 0.0
    assert plan.exhausted
    assert len(plan.fired) == 2


def test_fault_taxonomy():
    assert Preemption().fatal
    assert not SimulatedOOM().fatal
    assert not DeviceLoss(2).fatal
    assert DeviceLoss(2).n_lost == 2
    with pytest.raises(ValueError):
        DeviceLoss(0)


# ---------------------------------------------------------------------------
# Kill at EVERY chunk boundary, resume: bit-for-bit identical.
# ---------------------------------------------------------------------------

def test_sweep_schedules_kill_resume_every_boundary(tmp_path):
    scheds = tuning.all_schedules(64)
    base = sweep.sweep_schedules(KEY, scheds, DELAYS, N_TRIALS)
    n_chunks = N_TRIALS // 2
    for kill_at in range(n_chunks):
        root = tmp_path / f"kill{kill_at}"
        rc = _rcfg(root)
        plan = FaultPlan(faults={kill_at: Preemption()})
        with pytest.raises(SimulatedFault):
            resilient_sweep_schedules(KEY, scheds, DELAYS, N_TRIALS,
                                      resilience=rc, fault_plan=plan,
                                      sleep=_nosleep)
        rep = resilient_sweep_schedules(KEY, scheds, DELAYS, N_TRIALS,
                                        resilience=rc, fault_plan=plan,
                                        sleep=_nosleep)
        _assert_same(rep.result, base)
        assert rep.chunks_resumed == kill_at
        assert rep.chunks_computed == n_chunks - kill_at
        assert rep.chunks_total == n_chunks


def test_sweep_arrivals_kill_resume(tmp_path):
    scheds = tuning.all_schedules(64)
    arr = 300.0 * jax.random.uniform(KEY, (2, 6, 64))
    base = sweep.sweep_arrivals(arr, scheds, kernels=("a", "b"))
    rc = _rcfg(tmp_path)
    plan = FaultPlan(faults={2: Preemption()})
    with pytest.raises(SimulatedFault):
        resilient_sweep_arrivals(arr, scheds, kernels=("a", "b"),
                                 resilience=rc, fault_plan=plan,
                                 sleep=_nosleep)
    rep = resilient_sweep_arrivals(arr, scheds, kernels=("a", "b"),
                                   resilience=rc, fault_plan=plan,
                                   sleep=_nosleep)
    _assert_same(rep.result, base)
    assert rep.chunks_resumed == 2 and rep.chunks_computed == 1


# ---------------------------------------------------------------------------
# In-process supervision: backoff, restart accounting, straggler abort.
# ---------------------------------------------------------------------------

def test_nonfatal_fault_restarts_with_backoff(tmp_path):
    scheds = tuning.all_schedules(64)[:8]
    base = sweep.sweep_schedules(KEY, scheds, DELAYS, N_TRIALS)
    sleeps = []
    rc = _rcfg(tmp_path, backoff_base=0.5, backoff_cap=2.0)
    plan = FaultPlan(faults={1: SimulatedOOM(), 3: SimulatedOOM()})
    rep = resilient_sweep_schedules(KEY, scheds, DELAYS, N_TRIALS,
                                    resilience=rc, fault_plan=plan,
                                    sleep=sleeps.append)
    _assert_same(rep.result, base)
    assert rep.restarts == 2
    assert len(rep.faults) == 2
    assert len(sleeps) == 2 and sleeps[1] >= sleeps[0] > 0
    # nothing recomputed: chunks done before each fault stayed in memory
    assert rep.chunks_computed == N_TRIALS // 2


def test_straggler_watchdog_restarts_chunk(tmp_path):
    scheds = tuning.all_schedules(64)[:8]
    base = sweep.sweep_schedules(KEY, scheds, DELAYS, N_TRIALS)
    rc = _rcfg(tmp_path, straggler_factor=5.0, straggler_floor=0.0)
    plan = FaultPlan(straggle={3: 3600.0})
    rep = resilient_sweep_schedules(KEY, scheds, DELAYS, N_TRIALS,
                                    resilience=rc, fault_plan=plan,
                                    sleep=_nosleep)
    _assert_same(rep.result, base)
    assert rep.restarts == 1
    assert "chunk took" in rep.faults[0]
    assert plan.exhausted


def test_gives_up_after_max_restarts(tmp_path):
    scheds = tuning.all_schedules(64)[:4]
    rc = _rcfg(tmp_path, max_restarts=1)
    plan = FaultPlan(faults={0: SimulatedOOM(), 1: SimulatedOOM(),
                             2: SimulatedOOM()})
    with pytest.raises(RuntimeError, match="giving up after 1"):
        resilient_sweep_schedules(KEY, scheds, DELAYS, N_TRIALS,
                                  resilience=rc, fault_plan=plan,
                                  sleep=_nosleep)


def test_stale_store_from_different_run_is_wiped(tmp_path):
    scheds = tuning.all_schedules(64)[:4]
    rc = _rcfg(tmp_path)
    rep1 = resilient_sweep_schedules(KEY, scheds, DELAYS, N_TRIALS,
                                     resilience=rc, sleep=_nosleep)
    # a DIFFERENT key must not resume from this store
    other = jax.random.PRNGKey(9)
    base = sweep.sweep_schedules(other, scheds, DELAYS, N_TRIALS)
    rep2 = resilient_sweep_schedules(other, scheds, DELAYS, N_TRIALS,
                                     resilience=rc, sleep=_nosleep)
    _assert_same(rep2.result, base)
    assert rep2.chunks_resumed == 0, "stale chunks must not be reused"


def test_corrupt_chunk_checkpoint_is_recomputed(tmp_path):
    scheds = tuning.all_schedules(64)[:4]
    rc = _rcfg(tmp_path)
    base = sweep.sweep_schedules(KEY, scheds, DELAYS, N_TRIALS)
    resilient_sweep_schedules(KEY, scheds, DELAYS, N_TRIALS,
                              resilience=rc, sleep=_nosleep)
    # tear one chunk's npz: resume must recompute it, not crash or trust
    victim = tmp_path / "chunks" / "step_00000001" / "host_0000.npz"
    victim.write_bytes(victim.read_bytes()[:64])
    rep = resilient_sweep_schedules(KEY, scheds, DELAYS, N_TRIALS,
                                    resilience=rc, sleep=_nosleep)
    _assert_same(rep.result, base)
    assert rep.chunks_computed == 1 and rep.chunks_resumed == 3


# ---------------------------------------------------------------------------
# Tuner-grid wrappers reproduce their plain counterparts exactly.
# ---------------------------------------------------------------------------

def test_resilient_tune_barrier_matches_plain(tmp_path):
    base = tuning.tune_barrier(KEY, 64, delays=DELAYS, n_trials=4,
                               placements=("leaf_local", "central"))
    rc = _rcfg(tmp_path)
    plan = FaultPlan(faults={1: SimulatedOOM()})
    rep = resilient_tune_barrier(KEY, 64, delays=DELAYS, n_trials=4,
                                 placements=("leaf_local", "central"),
                                 resilience=rc, fault_plan=plan,
                                 sleep=_nosleep)
    _assert_same(rep.result, base)
    assert rep.result.names == base.names


def test_resilient_sweep_workloads_matches_plain(tmp_path):
    kernels = ("dotp_1Mi", "conv2d_256x256")
    base = tuning.sweep_workloads(KEY, kernels, 64, n_trials=4)
    rc = _rcfg(tmp_path)
    plan = FaultPlan(faults={0: Preemption()})
    with pytest.raises(SimulatedFault):
        resilient_sweep_workloads(KEY, kernels, 64, n_trials=4,
                                  resilience=rc, fault_plan=plan,
                                  sleep=_nosleep)
    rep = resilient_sweep_workloads(KEY, kernels, 64, n_trials=4,
                                    resilience=rc, fault_plan=plan,
                                    sleep=_nosleep)
    _assert_same(rep.result, base)
    assert rep.result.kernels == kernels


# ---------------------------------------------------------------------------
# Elastic re-sharding under simulated device loss (8-device subprocess;
# single-device hosts exercise the transparent fallback everywhere else).
# ---------------------------------------------------------------------------

def test_device_loss_single_device_insufficient(tmp_path):
    scheds = tuning.all_schedules(64)[:4]
    rc = _rcfg(tmp_path, min_devices=2)
    if len(jax.devices()) > 1:
        pytest.skip("single-device scenario")
    plan = FaultPlan(faults={1: DeviceLoss(1)})
    with pytest.raises(RuntimeError, match="survive"):
        resilient_sweep_schedules(KEY, scheds, DELAYS, N_TRIALS,
                                  resilience=rc, fault_plan=plan,
                                  sleep=_nosleep)


def test_elastic_reshard_multidevice(tmp_path):
    """8 host devices; a DeviceLoss(3) at chunk 1 shrinks the
    schedule-axis mesh 8 -> 4 (5 survivors, 128 points), the sweep
    continues, and the result — mixing full-mesh chunk 0 with
    shrunken-mesh chunks — equals the unsharded run bit for bit.  A
    second kill-then-resume on the shrunken mesh stays exact too, for
    both sweep_schedules and sweep_arrivals grids."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        + os.environ.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = str(REPO / "src")
    env["RESILIENCE_TMP"] = str(tmp_path)
    script = """
import os
import jax
import numpy as np
import pytest
from repro.core import sweep, tuning, placement
from repro.runtime import (DeviceLoss, FaultPlan, Preemption,
                           ResilienceConfig, SimulatedFault,
                           resilient_sweep_arrivals,
                           resilient_sweep_schedules)

assert len(jax.devices()) == 8, jax.devices()
tmp = os.environ["RESILIENCE_TMP"]
key = jax.random.PRNGKey(0)
# 32 compositions x 4 strategies = 128 points: divisible by 8, 4, 2.
scheds, placs = tuning._cross_placements(
    tuning.all_schedules(64), placement.STRATEGIES, sweep.DEFAULT)
base = sweep.sweep_schedules(key, scheds, (0.0, 512.0), 8,
                             placements=placs, shard=False)

rc = ResilienceConfig(ckpt_dir=tmp + "/sched", trial_chunk=2,
                      backoff_base=0.0, backoff_cap=0.0)
plan = FaultPlan(faults={1: DeviceLoss(3), 2: Preemption()})
try:
    resilient_sweep_schedules(key, scheds, (0.0, 512.0), 8,
                              placements=placs, resilience=rc,
                              fault_plan=plan, sleep=lambda s: None)
    raise SystemExit("expected preemption")
except SimulatedFault:
    pass
rep = resilient_sweep_schedules(key, scheds, (0.0, 512.0), 8,
                                placements=placs, resilience=rc,
                                fault_plan=plan, sleep=lambda s: None)
np.testing.assert_array_equal(np.asarray(rep.result.span_cycles),
                              np.asarray(base.span_cycles))
np.testing.assert_array_equal(np.asarray(rep.result.exit_time),
                              np.asarray(base.exit_time))
np.testing.assert_array_equal(np.asarray(rep.result.mean_residency),
                              np.asarray(base.mean_residency))
assert rep.chunks_resumed == 2, rep      # chunks 0,1 from the killed run

# arrivals grid: lose 4 devices mid-run, shrink 8 -> 4, stay exact
arr = 300.0 * jax.random.uniform(key, (2, 8, 64))
abase = sweep.sweep_arrivals(arr, scheds, placements=placs, shard=False)
rc2 = ResilienceConfig(ckpt_dir=tmp + "/arr", trial_chunk=2,
                       backoff_base=0.0, backoff_cap=0.0)
plan2 = FaultPlan(faults={2: DeviceLoss(4)})
arep = resilient_sweep_arrivals(arr, scheds, placements=placs,
                                resilience=rc2, fault_plan=plan2,
                                sleep=lambda s: None)
np.testing.assert_array_equal(np.asarray(arep.result.span_cycles),
                              np.asarray(abase.span_cycles))
np.testing.assert_array_equal(np.asarray(arep.result.exit_time),
                              np.asarray(abase.exit_time))
assert arep.device_history == [8, 4], arep.device_history
assert arep.restarts == 1, arep
print("device history:", arep.device_history)
print("elastic reshard ok")
"""
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "elastic reshard ok" in r.stdout
    assert "device history: [8, 4]" in r.stdout


# ---------------------------------------------------------------------------
# Persistent schedule cache: process-level hits, corruption rejection.
# ---------------------------------------------------------------------------

@pytest.fixture
def cache_env(tmp_path, monkeypatch):
    monkeypatch.setenv(schedule_cache.CACHE_ENV, str(tmp_path / "cache"))
    schedule_cache.reset_stats()
    tuning.tuned_for_workload.cache_clear()
    yield tmp_path / "cache"
    tuning.tuned_for_workload.cache_clear()
    schedule_cache.reset_stats()


def test_schedule_cache_disabled_without_env(monkeypatch):
    monkeypatch.delenv(schedule_cache.CACHE_ENV, raising=False)
    assert schedule_cache.cache_dir() is None
    assert schedule_cache.load(("k",)) is None
    schedule_cache.store(("k",), {"x": 1})     # no-op, no crash


def test_schedule_cache_roundtrip_and_hit(cache_env, monkeypatch):
    sched, plc = tuning.tuned_for_workload("dotp_1Mi", 64)
    assert schedule_cache.STATS["stores"] == 1
    tuning.tuned_for_workload.cache_clear()
    # sabotage the tuner: a disk hit must perform ZERO recomputation
    monkeypatch.setattr(
        tuning, "tune_for_workload",
        lambda *a, **k: pytest.fail("cache hit must not re-sweep"))
    sched2, plc2 = tuning.tuned_for_workload("dotp_1Mi", 64)
    assert (sched2, plc2) == (sched, plc)
    assert schedule_cache.STATS["hits"] == 1


def test_schedule_cache_detects_corruption(cache_env):
    sched, plc = tuning.tuned_for_workload(
        "dotp_1Mi", 64, placements=("leaf_local", "central"))
    tuning.tuned_for_workload.cache_clear()
    entry = next(cache_env.glob("*.json"))
    # bit-flip INSIDE the payload: still valid JSON, wrong checksum
    data = json.loads(entry.read_text())
    data["payload"]["schedule"]["sizes"][0] = 999
    entry.write_text(json.dumps(data))
    sched2, plc2 = tuning.tuned_for_workload(
        "dotp_1Mi", 64, placements=("leaf_local", "central"))
    assert schedule_cache.STATS["corrupt"] == 1
    assert (sched2, plc2) == (sched, plc), "corrupt entry must recompute"
    # the rewritten entry now round-trips
    tuning.tuned_for_workload.cache_clear()
    sched3, plc3 = tuning.tuned_for_workload(
        "dotp_1Mi", 64, placements=("leaf_local", "central"))
    assert (sched3, plc3) == (sched, plc)
    assert schedule_cache.STATS["hits"] == 1


def test_schedule_cache_truncated_entry(cache_env):
    sched, plc = tuning.tuned_for_workload("conv2d_256x256", 64)
    tuning.tuned_for_workload.cache_clear()
    entry = next(cache_env.glob("*.json"))
    entry.write_text(entry.read_text()[:37])
    sched2, plc2 = tuning.tuned_for_workload("conv2d_256x256", 64)
    assert (sched2, plc2) == (sched, plc)
    assert schedule_cache.STATS["corrupt"] == 1


def test_schedule_cache_key_separation(cache_env):
    s64, _ = tuning.tuned_for_workload("dotp_1Mi", 64)
    s256, _ = tuning.tuned_for_workload("dotp_1Mi", 256)
    assert len(list(cache_env.glob("*.json"))) == 2
    assert s64.n_pes == 64 and s256.n_pes == 256


def test_fiveg_modes_read_through_cache(cache_env, monkeypatch):
    from repro.core import fiveg
    from repro.core.topology import TeraPoolConfig
    cfg = TeraPoolConfig(n_pes=64)
    sched = fiveg._tuned_schedule(64, 100.0, False, cfg)
    pair = fiveg._placed_schedule(64, 100.0, cfg)
    fiveg._tuned_schedule.cache_clear()
    fiveg._placed_schedule.cache_clear()
    monkeypatch.setattr(tuning, "best_schedule",
                        lambda *a, **k: pytest.fail("must hit disk"))
    monkeypatch.setattr(tuning, "best_placed_schedule",
                        lambda *a, **k: pytest.fail("must hit disk"))
    assert fiveg._tuned_schedule(64, 100.0, False, cfg) == sched
    assert fiveg._placed_schedule(64, 100.0, cfg) == pair


def test_code_version_is_stable():
    assert schedule_cache.code_version() == schedule_cache.code_version()
    assert len(schedule_cache.code_version()) == 16


# ---------------------------------------------------------------------------
# Multi-host chunk stores: interleaved ownership over one shared
# checkpoint directory.
# ---------------------------------------------------------------------------

def test_multihost_config_validates():
    with pytest.raises(ValueError, match="host_count"):
        ResilienceConfig(ckpt_dir="x", host_count=0)
    with pytest.raises(ValueError, match="host_id"):
        ResilienceConfig(ckpt_dir="x", host_id=2, host_count=2)
    with pytest.raises(ValueError, match="host_id"):
        ResilienceConfig(ckpt_dir="x", host_id=-1)


def test_multihost_interleaved_chunks_arrivals(tmp_path):
    """Two hosts share one store: host 0 computes chunks 0 and 2 then
    raises listing the foreign chunks 1 and 3; host 1 restores 0/2,
    fills 1/3; a host-0 rerun then assembles the full grid purely from
    the store — bit-for-bit equal to the plain engine."""
    scheds = tuning.all_schedules(64)
    arr = 300.0 * jax.random.uniform(KEY, (2, 8, 64))
    base = sweep.sweep_arrivals(arr, scheds, kernels=("a", "b"))
    store = tmp_path / "shared"
    rc0 = ResilienceConfig(ckpt_dir=str(store), trial_chunk=2,
                           host_id=0, host_count=2)
    with pytest.raises(RuntimeError, match=r"chunk\(s\) \[1, 3\]"):
        resilient_sweep_arrivals(arr, scheds, kernels=("a", "b"),
                                 resilience=rc0, sleep=_nosleep)
    # host 0 published exactly its own interleaved chunks
    assert (store / "step_00000000").exists()
    assert (store / "step_00000002").exists()
    assert not (store / "step_00000001").exists()
    rc1 = ResilienceConfig(ckpt_dir=str(store), trial_chunk=2,
                           host_id=1, host_count=2)
    rep1 = resilient_sweep_arrivals(arr, scheds, kernels=("a", "b"),
                                    resilience=rc1, sleep=_nosleep)
    _assert_same(rep1.result, base)
    assert rep1.chunks_resumed == 2 and rep1.chunks_computed == 2
    # rerun of host 0: everything restores, nothing recomputes
    rep0 = resilient_sweep_arrivals(arr, scheds, kernels=("a", "b"),
                                    resilience=rc0, sleep=_nosleep)
    _assert_same(rep0.result, base)
    assert rep0.chunks_resumed == 4 and rep0.chunks_computed == 0


def test_multihost_three_way_schedules(tmp_path):
    """Three hosts over a 4-chunk delay sweep; completion in arbitrary
    host order still assembles the exact plain-engine result."""
    scheds = tuning.all_schedules(64)[:8]
    base = sweep.sweep_schedules(KEY, scheds, DELAYS, N_TRIALS)
    store = tmp_path / "shared3"

    def run_host(h):
        rc = ResilienceConfig(ckpt_dir=str(store), trial_chunk=2,
                              host_id=h, host_count=3)
        return resilient_sweep_schedules(KEY, scheds, DELAYS, N_TRIALS,
                                         resilience=rc, sleep=_nosleep)

    # hosts 1 and 2 go first: each owns only a strict subset
    with pytest.raises(RuntimeError, match=r"host 1/3"):
        run_host(1)                      # owns chunk 1; misses 0, 2, 3
    with pytest.raises(RuntimeError, match=r"chunk\(s\) \[0, 3\]"):
        run_host(2)                      # owns chunk 2; restores 1
    rep0 = run_host(0)                   # owns 0 and 3: completes
    _assert_same(rep0.result, base)
    assert rep0.chunks_computed == 2 and rep0.chunks_resumed == 2


def test_multihost_default_is_single_host(tmp_path):
    rc = _rcfg(tmp_path)
    assert rc.host_id == 0 and rc.host_count == 1
    scheds = tuning.all_schedules(64)[:4]
    rep = resilient_sweep_schedules(KEY, scheds, DELAYS, N_TRIALS,
                                    resilience=rc, sleep=_nosleep)
    assert rep.chunks_computed == N_TRIALS // 2


# ---------------------------------------------------------------------------
# Schedule cache TTL + LRU size-capped eviction.
# ---------------------------------------------------------------------------

@pytest.fixture
def bounded_cache(cache_env, monkeypatch):
    """The cache_env store with the TTL/MAX knobs cleared for explicit
    per-test control."""
    monkeypatch.delenv(schedule_cache.TTL_ENV, raising=False)
    monkeypatch.delenv(schedule_cache.MAX_ENV, raising=False)
    return cache_env


def _backdate(path, seconds):
    old = time.time() - seconds
    os.utime(path, (old, old))


def test_schedule_cache_ttl_expires_entries(bounded_cache, monkeypatch):
    monkeypatch.setenv(schedule_cache.TTL_ENV, "100")
    sched, plc = tuning.tuned_for_workload("dotp_1Mi", 64)
    tuning.tuned_for_workload.cache_clear()
    _backdate(next(bounded_cache.glob("*.json")), 1000)
    schedule_cache.reset_stats()
    sched2, plc2 = tuning.tuned_for_workload("dotp_1Mi", 64)
    # the stale entry was evicted, read as a miss, and re-tuned
    assert schedule_cache.STATS["evictions"] >= 1
    assert schedule_cache.STATS["misses"] == 1
    assert schedule_cache.STATS["stores"] == 1
    assert (sched2, plc2) == (sched, plc)
    # the fresh rewrite serves hits again
    tuning.tuned_for_workload.cache_clear()
    assert tuning.tuned_for_workload("dotp_1Mi", 64) == (sched, plc)
    assert schedule_cache.STATS["hits"] == 1


def test_schedule_cache_lru_size_cap(bounded_cache, monkeypatch):
    monkeypatch.setenv(schedule_cache.MAX_ENV, "2")
    schedule_cache.store(("k1",), {"v": 1})
    _backdate(schedule_cache._entry_path(bounded_cache, ("k1",)), 300)
    schedule_cache.store(("k2",), {"v": 2})
    _backdate(schedule_cache._entry_path(bounded_cache, ("k2",)), 200)
    assert schedule_cache.STATS["evictions"] == 0
    schedule_cache.store(("k3",), {"v": 3})   # cap hit: k1 is LRU
    assert schedule_cache.STATS["evictions"] == 1
    assert schedule_cache.load(("k1",)) is None
    assert schedule_cache.load(("k2",)) == {"v": 2}
    assert schedule_cache.load(("k3",)) == {"v": 3}
    assert len(list(bounded_cache.glob("*.json"))) == 2


def test_schedule_cache_hit_touches_lru_clock(bounded_cache, monkeypatch):
    monkeypatch.setenv(schedule_cache.MAX_ENV, "2")
    schedule_cache.store(("k1",), {"v": 1})
    _backdate(schedule_cache._entry_path(bounded_cache, ("k1",)), 300)
    schedule_cache.store(("k2",), {"v": 2})
    _backdate(schedule_cache._entry_path(bounded_cache, ("k2",)), 200)
    # a hit on k1 makes it most-recently-used: k2 gets evicted instead
    assert schedule_cache.load(("k1",)) == {"v": 1}
    schedule_cache.store(("k3",), {"v": 3})
    assert schedule_cache.load(("k2",)) is None
    assert schedule_cache.load(("k1",)) == {"v": 1}
    assert schedule_cache.load(("k3",)) == {"v": 3}


def test_schedule_cache_evict_direct_and_unbounded(bounded_cache):
    schedule_cache.store(("a",), {"v": 1})
    schedule_cache.store(("b",), {"v": 2})
    # no TTL, no cap: evict is a no-op
    assert schedule_cache.evict() == 0
    assert schedule_cache.STATS["evictions"] == 0
    # malformed knobs are ignored, never fatal
    os.environ[schedule_cache.MAX_ENV] = "not-a-number"
    try:
        assert schedule_cache.evict() == 0
    finally:
        del os.environ[schedule_cache.MAX_ENV]
    assert len(list(bounded_cache.glob("*.json"))) == 2

# ---------------------------------------------------------------------------
# SweepReport fault ledger: per-class counts + total backoff charged.
# ---------------------------------------------------------------------------

def test_report_ledger_counts_faults_by_class(tmp_path):
    scheds = tuning.all_schedules(64)[:8]
    sleeps = []
    rc = _rcfg(tmp_path, trial_chunk=1, backoff_base=0.25,
               backoff_cap=1.0, straggler_factor=5.0,
               straggler_floor=0.0)
    # trial_chunk=1 -> 8 chunks: the OOM restart at chunk 1 clears the
    # watchdog baseline, chunks 1-4 rebuild it, the straggler at chunk
    # 5 trips it
    plan = FaultPlan(faults={1: SimulatedOOM()}, straggle={5: 3600.0})
    rep = resilient_sweep_schedules(KEY, scheds, DELAYS, N_TRIALS,
                                    resilience=rc, fault_plan=plan,
                                    sleep=sleeps.append)
    assert rep.fault_counts == {"SimulatedOOM": 1, "StragglerAbort": 1}
    assert sum(rep.fault_counts.values()) == len(rep.faults) == 2
    # every second the supervisor slept in backoff is on the ledger
    assert rep.backoff_seconds == pytest.approx(sum(sleeps))
    assert rep.backoff_seconds > 0


def test_report_ledger_empty_on_clean_run(tmp_path):
    scheds = tuning.all_schedules(64)[:4]
    rep = resilient_sweep_schedules(KEY, scheds, DELAYS, 4,
                                    resilience=_rcfg(tmp_path),
                                    sleep=_nosleep)
    assert rep.fault_counts == {} and rep.backoff_seconds == 0.0


# ---------------------------------------------------------------------------
# Preemption end-to-end: the process DIES mid-sweep; a fresh process
# resumes from the chunk store and lands bit-for-bit on the plain run.
# ---------------------------------------------------------------------------

_PREEMPT_SCRIPT = """
import os
import jax
import numpy as np
from repro.core import sweep, tuning
from repro.runtime import (FaultPlan, Preemption, ResilienceConfig,
                           SimulatedFault, resilient_sweep_arrivals)

tmp = os.environ["RESILIENCE_TMP"]
phase = os.environ["RESILIENCE_PHASE"]
key = jax.random.PRNGKey(0)
scheds = tuning.all_schedules(64)
arr = np.asarray(300.0 * jax.random.uniform(key, (2, 6, 64)), np.float32)
rc = ResilienceConfig(ckpt_dir=tmp + "/chunks", trial_chunk=2,
                      backoff_base=0.0, backoff_cap=0.0)
if phase == "A":
    plan = FaultPlan(faults={1: Preemption()})
    try:
        resilient_sweep_arrivals(arr, scheds, kernels=("a", "b"),
                                 resilience=rc, fault_plan=plan,
                                 sleep=lambda s: None)
    except SimulatedFault:
        print("preempted after chunk 0")
        raise SystemExit(17)          # the process dies; the store survives
    raise SystemExit("preemption never fired")
rep = resilient_sweep_arrivals(arr, scheds, kernels=("a", "b"),
                               resilience=rc, sleep=lambda s: None)
base = sweep.sweep_arrivals(arr, scheds, kernels=("a", "b"))
np.testing.assert_array_equal(np.asarray(rep.result.span_cycles),
                              np.asarray(base.span_cycles))
np.testing.assert_array_equal(np.asarray(rep.result.exit_time),
                              np.asarray(base.exit_time))
np.testing.assert_array_equal(np.asarray(rep.result.energy),
                              np.asarray(base.energy))
assert rep.chunks_resumed == 1 and rep.chunks_computed == 2, rep
print("cross-process resume ok")
"""


def test_preemption_cross_process_resume(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["RESILIENCE_TMP"] = str(tmp_path)
    env["RESILIENCE_PHASE"] = "A"
    a = subprocess.run([sys.executable, "-c", _PREEMPT_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert a.returncode == 17, a.stdout[-3000:] + a.stderr[-3000:]
    assert "preempted after chunk 0" in a.stdout
    assert (tmp_path / "chunks").is_dir()   # the store outlived process A
    env["RESILIENCE_PHASE"] = "B"
    b = subprocess.run([sys.executable, "-c", _PREEMPT_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert b.returncode == 0, b.stdout[-3000:] + b.stderr[-3000:]
    assert "cross-process resume ok" in b.stdout


# ---------------------------------------------------------------------------
# Concurrent cache writers: stress + deterministic vanishing-file races.
# ---------------------------------------------------------------------------

_STRESS_SCRIPT = """
import os
from repro.runtime import schedule_cache

wid = int(os.environ["STRESS_WORKER"])
for i in range(40):
    k = ("stress", (wid + i) % 6)
    schedule_cache.store(k, {"worker": wid, "iter": i})
    schedule_cache.load(k)
    schedule_cache.load(("stress", (wid + i + 1) % 6))
# torn or truncated entries would land in the corrupt counter: a race
# must read as a benign miss, never as corruption
assert schedule_cache.STATS["corrupt"] == 0, schedule_cache.STATS
print("worker", wid, "ok")
"""


def test_schedule_cache_multiprocess_stress(cache_env, monkeypatch):
    """Four writer processes hammer six overlapping keys while the cap
    forces evictions on every store AND the parent concurrently runs
    the evictor: nobody ever reads a torn entry."""
    monkeypatch.setenv(schedule_cache.MAX_ENV, "3")
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    procs = []
    for wid in range(4):
        env_w = dict(env)
        env_w["STRESS_WORKER"] = str(wid)
        procs.append(subprocess.Popen(
            [sys.executable, "-c", _STRESS_SCRIPT], env=env_w,
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True))
    deadline = time.time() + 600
    while any(p.poll() is None for p in procs) and time.time() < deadline:
        schedule_cache.evict()       # adversarial concurrent evictor
        time.sleep(0.01)
    for wid, p in enumerate(procs):
        out, err = p.communicate(timeout=60)
        assert p.returncode == 0, f"worker {wid}:\n{out[-2000:]}{err[-2000:]}"
        assert f"worker {wid} ok" in out
    # the parent's own stats saw no corruption either
    assert schedule_cache.STATS["corrupt"] == 0


def test_schedule_cache_load_tolerates_vanishing_entry(cache_env,
                                                       monkeypatch):
    schedule_cache.store(("race-load",), {"v": 1})
    real = Path.read_text
    armed = {"on": True}

    def vanish(self, *a, **kw):
        if armed["on"] and self.parent == cache_env:
            armed["on"] = False          # one-shot: entry "vanishes" once
            raise FileNotFoundError(str(self))
        return real(self, *a, **kw)

    monkeypatch.setattr(Path, "read_text", vanish)
    assert schedule_cache.load(("race-load",)) is None
    assert schedule_cache.STATS["races"] == 1
    assert schedule_cache.STATS["corrupt"] == 0
    # the entry itself was never unlinked: the next read hits
    assert schedule_cache.load(("race-load",)) == {"v": 1}


def test_schedule_cache_load_tolerates_vanishing_stat(cache_env,
                                                      monkeypatch):
    monkeypatch.setenv(schedule_cache.TTL_ENV, "3600")
    schedule_cache.store(("race-stat",), {"v": 2})
    real = schedule_cache._expired
    armed = {"on": True}

    def vanish(path, now):
        if armed["on"]:
            armed["on"] = False
            raise FileNotFoundError(str(path))
        return real(path, now)

    monkeypatch.setattr(schedule_cache, "_expired", vanish)
    assert schedule_cache.load(("race-stat",)) is None
    assert schedule_cache.STATS["races"] >= 1
    assert schedule_cache.STATS["corrupt"] == 0
    assert schedule_cache.load(("race-stat",)) == {"v": 2}


def test_schedule_cache_store_tolerates_vanishing_root(cache_env,
                                                       monkeypatch):
    real = os.replace
    armed = {"left": 2}

    def vanish(src, dst):
        if armed["left"] > 0:
            armed["left"] -= 1
            raise FileNotFoundError(dst)
        return real(src, dst)

    monkeypatch.setattr(schedule_cache.os, "replace", vanish)
    schedule_cache.store(("race-store",), {"v": 3})   # gives up silently
    assert schedule_cache.STATS["races"] == 2         # both attempts raced
    assert schedule_cache.STATS["stores"] == 0
    assert not list(cache_env.glob("*.tmp"))          # temp files reaped
    # with the race gone the very next publish lands
    schedule_cache.store(("race-store",), {"v": 3})
    assert schedule_cache.load(("race-store",)) == {"v": 3}


def test_schedule_cache_evict_tolerates_vanishing_entry(cache_env,
                                                        monkeypatch):
    monkeypatch.setenv(schedule_cache.TTL_ENV, "3600")
    schedule_cache.store(("race-evict", 1), {"v": 1})
    schedule_cache.store(("race-evict", 2), {"v": 2})

    calls = {"n": 0}
    real = schedule_cache._expired

    def vanish_first(path, now):
        calls["n"] += 1
        if calls["n"] == 1:
            raise FileNotFoundError(str(path))
        return real(path, now)

    monkeypatch.setattr(schedule_cache, "_expired", vanish_first)
    assert schedule_cache.evict() == 0    # skips the racer, keeps going
    assert calls["n"] == 2                # still visited the second entry
    assert schedule_cache.STATS["races"] == 1
