"""Per-kernel allclose sweeps against the pure-jnp oracles (shapes &
dtypes), interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


@pytest.mark.parametrize("n", [64, 1000, 4096, 10000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_axpy(n, dtype):
    x, y = _arr((n,), dtype), _arr((n,), dtype)
    got = ops.axpy(1.7, x, y)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref.axpy(1.7, x, y), np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("n", [128, 1000, 8192])
@pytest.mark.parametrize("radix", [0, 2, 4, 16])
def test_dotp(n, radix):
    x, y = _arr((n,)), _arr((n,))
    np.testing.assert_allclose(ops.dotp(x, y, radix=radix), ref.dotp(x, y),
                               rtol=1e-4)


@pytest.mark.parametrize("shape", [(8, 16, 8), (100, 60, 72),
                                   (256, 512, 128), (129, 257, 65)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul(shape, dtype):
    m, k, n = shape
    x, w = _arr((m, k), dtype), _arr((k, n), dtype)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(ops.matmul(x, w), ref.matmul(x, w),
                               rtol=tol, atol=tol * k ** 0.5)


@pytest.mark.parametrize("hw", [(8, 8), (16, 20), (32, 32)])
def test_conv2d(hw):
    img = _arr((3, *hw))
    kern = _arr((3, 3))
    np.testing.assert_allclose(ops.conv2d(img, kern), ref.conv2d(img, kern),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n", [8, 64, 256])
def test_dct(n):
    x = _arr((33, n))
    np.testing.assert_allclose(ops.dct(x), ref.dct(x), rtol=1e-3, atol=1e-3)


def test_dct_orthonormal():
    b = ref.dct_basis(32)
    np.testing.assert_allclose(b @ b.T, np.eye(32), atol=1e-5)


@pytest.mark.parametrize("n", [16, 64, 256, 1024])
def test_fft4_vs_numpy(n):
    re, im = _arr((3, n), scale=0.5), _arr((3, n), scale=0.5)
    gr, gi = ops.fft4(re, im)
    idx = np.asarray(ref.digit_reverse_indices(n))
    want = np.fft.fft(np.asarray(re) + 1j * np.asarray(im), axis=-1)
    np.testing.assert_allclose(np.asarray(gr)[:, idx], want.real,
                               rtol=1e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(gi)[:, idx], want.imag,
                               rtol=1e-3, atol=2e-3)


@pytest.mark.parametrize("s,d", [(64, 16), (128, 32), (256, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(s, d, causal):
    q, k, v = (_arr((2, 2, s, d), jnp.float32, 0.5) for _ in range(3))
    got = ops.flash_attention(q, k, v, causal=causal)
    want = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 6), st.integers(1, 4))
def test_dotp_tree_equals_central_property(blocks, radix_pow):
    """k-ary tree reduction == central accumulator for any shape/radix
    (the paper's invariant: barrier radix never changes the result)."""
    n = blocks * 333
    x = jnp.asarray(RNG.standard_normal(n), jnp.float32)
    y = jnp.asarray(RNG.standard_normal(n), jnp.float32)
    central = ops.dotp(x, y, radix=0)
    tree = ops.dotp(x, y, radix=2 ** radix_pow)
    np.testing.assert_allclose(central, tree, rtol=1e-5)
