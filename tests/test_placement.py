"""Bank-aware counter placement: strategy structure, the leaf-local
backward-compat oracle (placement-derived latencies == legacy span
heuristic, bit-for-bit), per-bank contention semantics validated
against an independent bank-queue oracle, the one-compile property of
composition x placement x delay sweeps, and the placed 5G sync mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (barrier, barrier_sim, fiveg, placement, sweep,
                        tuning)
from repro.core.topology import DEFAULT

KEY = jax.random.PRNGKey(0)
DELAYS = (0.0, 128.0, 512.0, 2048.0)


# ---------------------------------------------------------------------------
# Placement structure.
# ---------------------------------------------------------------------------

def test_strategy_structure():
    s = barrier.mixed_radix_tree((8, 16, 8))
    for strat in placement.STRATEGIES:
        pl = placement.place_counters(s, strat)
        assert pl.strategy == strat
        assert [len(row) for row in pl.banks] == [128, 8, 1]
        assert [len(row) for row in pl.latencies] == [128, 8, 1]
        for brow in pl.banks:
            assert all(0 <= b < DEFAULT.n_banks for b in brow)
    with pytest.raises(ValueError):
        placement.place_counters(s, "nope")


def test_contention_exposure_by_strategy():
    s = barrier.mixed_radix_tree((8, 16, 8))
    # leaf_local and tile_interleaved are conflict-free; group_hub piles
    # the 16 Tile counters of each Group on one hub bank; central piles
    # everything on bank 0.
    assert placement.place_counters(s, "leaf_local")\
        .shared_bank_counters() == (0, 0, 0)
    assert placement.place_counters(s, "tile_interleaved")\
        .shared_bank_counters() == (0, 0, 0)
    assert placement.place_counters(s, "group_hub")\
        .shared_bank_counters() == (128, 0, 0)
    assert placement.place_counters(s, "central")\
        .shared_bank_counters() == (128, 8, 0)


def test_explicit_placement_encoding():
    s = barrier.mixed_radix_tree((8, 16, 8))
    pl = placement.explicit_placement(s, bank_offsets=[32, 0, 7],
                                      bank_strides=[8, 0, 4])
    assert pl.banks[0][:3] == (32, 40, 48)
    assert set(pl.banks[1]) == {0}            # stride 0 -> one bank
    assert pl.banks[2] == (7,)
    assert pl.shared_bank_counters()[1] == 8
    with pytest.raises(ValueError):
        placement.explicit_placement(s, bank_offsets=[0, 0])
    with pytest.raises(ValueError):
        placement.explicit_placement(s, bank_offsets=[0, 0, 0],
                                     bank_strides=[1, 1])


# ---------------------------------------------------------------------------
# Backward-compat oracle: leaf-local == the deprecated span heuristic.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_pes", [64, 256, 1024])
def test_leaf_local_reproduces_span_heuristic(n_pes):
    """The paper's placement, derived from PE<->bank locality classes,
    must reproduce the legacy 1/3/5 per-level latencies bit-for-bit for
    EVERY composition — the deprecation-safety oracle for
    topology.access_latency."""
    for s in tuning.all_schedules(n_pes):
        pl = placement.place_counters(s, "leaf_local")
        for lvl, row in zip(s.levels, pl.latencies):
            want = DEFAULT.access_latency(lvl.span)
            assert all(lat == want for lat in row), (s.name, lvl.span)


def test_leaf_local_simulation_matches_unplaced_bitforbit():
    arr = 700.0 * jax.random.uniform(KEY, (1024,))
    for sizes in [(8, 16, 8), (2, 8, 8, 8), (1024,), (4, 256)]:
        s = barrier.mixed_radix_tree(sizes)
        pl = placement.place_counters(s, "leaf_local")
        got = barrier_sim.simulate(arr, s, placement=pl)
        ref = barrier_sim.simulate_reference(arr, s)
        for name, a, b in zip(got._fields, got, ref):
            assert float(a) == float(b), (sizes, name)


# ---------------------------------------------------------------------------
# Per-bank serialization: contention is real and matches the
# independent bank-queue oracle.
# ---------------------------------------------------------------------------

def test_same_bank_siblings_contend():
    """Two sibling counters on ONE bank must serialize against each
    other: strictly larger span than the same tree with the counters on
    distinct banks (the subsystem's acceptance criterion)."""
    s = barrier.mixed_radix_tree((512, 2))
    shared = placement.explicit_placement(s, bank_offsets=[0, 0],
                                          bank_strides=[0, 0])
    distinct = placement.explicit_placement(s, bank_offsets=[0, 0])
    arr = jnp.zeros(1024)
    span_shared = float(barrier_sim.simulate(
        arr, s, placement=shared).span_cycles)
    span_distinct = float(barrier_sim.simulate(
        arr, s, placement=distinct).span_cycles)
    # 1024 zero-delay atomics through one bank vs two parallel queues
    # of 512: the shared-bank barrier pays the full serialization.
    assert span_shared > span_distinct + 500


def test_scanned_core_matches_bank_queue_oracle():
    """The scanned per-bank serialization == explicit per-bank request
    queues (independent numpy oracle), for every strategy including the
    heavily contended ones."""
    for sizes in [(8, 16, 8), (2, 2, 2, 2, 2, 2, 2, 2, 2, 2), (1024,),
                  (4, 256), (32, 32)]:
        s = barrier.mixed_radix_tree(sizes)
        for strat in placement.STRATEGIES:
            pl = placement.place_counters(s, strat)
            arr = 300.0 * jax.random.uniform(jax.random.PRNGKey(7),
                                             (1024,))
            got = barrier_sim.simulate(arr, s, placement=pl)
            ref = placement.simulate_placed_reference(arr, s, pl)
            for name, a, b in zip(got._fields, got, ref):
                assert float(a) == pytest.approx(
                    float(b), rel=1e-6), (sizes, strat, name)


def test_placed_reference_batched_shapes():
    s = barrier.mixed_radix_tree((8, 8), n_pes=64)
    pl = placement.place_counters(s, "group_hub")
    arr = 100.0 * jax.random.uniform(KEY, (2, 3, 64))
    got = barrier_sim.simulate(arr, s, placement=pl)
    ref = placement.simulate_placed_reference(arr, s, pl)
    assert got.exit_time.shape == ref.exit_time.shape == (2, 3)
    np.testing.assert_allclose(np.asarray(got.exit_time),
                               np.asarray(ref.exit_time), rtol=1e-6)


# ---------------------------------------------------------------------------
# One-compile property of placement sweeps.
# ---------------------------------------------------------------------------

def test_composition_placement_delay_grid_compiles_once():
    """Full composition x placement x delay grid at N=256 traces the
    scanned core exactly once."""
    jax.clear_caches()
    barrier_sim.TRACE_COUNTS.clear()
    res = tuning.tune_barrier(jax.random.PRNGKey(11), n_pes=256,
                              delays=DELAYS, n_trials=4,
                              placements=placement.STRATEGIES)
    jax.block_until_ready(res.span_cycles)
    # 128 compositions x 4 strategies, aligned metadata.
    assert res.span_cycles.shape == (512, 4, 4)
    assert len(res.placements) == 512
    assert barrier_sim.core_traces() == 1


def test_full_placed_tuner_sweep_1024_compiles_once():
    """The acceptance-criterion sweep: ALL 512 compositions x every
    placement strategy x delays at N=1024 through ONE trace of the
    scanned core, and the placed best matches or beats the best
    leaf-local uniform radix at every delay."""
    jax.clear_caches()
    barrier_sim.TRACE_COUNTS.clear()
    res = tuning.tune_barrier(jax.random.PRNGKey(42), delays=DELAYS,
                              n_trials=2,
                              placements=placement.STRATEGIES)
    jax.block_until_ready(res.span_cycles)
    assert res.span_cycles.shape == (2048, 4, 2)
    assert barrier_sim.core_traces() == 1
    for p in tuning.best_per_delay(res):
        assert p.mean_span <= p.uniform_span, (p.delay, p.schedule.name)
        # the jointly placed winner carries its placement metadata
        assert p.placement is None or p.placement.strategy in \
            placement.STRATEGIES


def test_leaf_local_axis_matches_unplaced_sweep_bitforbit():
    """A placement sweep restricted to leaf_local reproduces the
    placement-free tuner column-for-column."""
    scheds = tuning.all_schedules(64)
    base = tuning.tune_barrier(KEY, 64, delays=(0.0, 512.0), n_trials=4)
    placed = tuning.tune_barrier(KEY, 64, delays=(0.0, 512.0), n_trials=4,
                                 placements=("leaf_local",))
    assert placed.span_cycles.shape == base.span_cycles.shape
    np.testing.assert_array_equal(np.asarray(placed.span_cycles),
                                  np.asarray(base.span_cycles))
    assert placed.names == tuple(s.name + "@leaf_local" for s in scheds)


def test_tune_barrier_rejects_placement_objects():
    s = barrier.mixed_radix_tree((8, 8), n_pes=64)
    pl = placement.place_counters(s, "central")
    with pytest.raises(TypeError):
        tuning.tune_barrier(KEY, 64, placements=(pl,))


# ---------------------------------------------------------------------------
# Joint (schedule, placement) selection + the placed 5G mode.
# ---------------------------------------------------------------------------

def test_best_placed_schedule_dominates_contended_strategies():
    sched, pl = tuning.best_placed_schedule(KEY, 256, delay=64.0,
                                            n_trials=4)
    assert sched.n_pes == 256
    # in-model, the paper's conflict-free local placement dominates, so
    # the joint tuner must never pick a strictly contended strategy
    assert pl.shared_bank_counters() == (0,) * sched.n_levels


def test_5g_placed_mode():
    app = fiveg.FiveGConfig(n_rx=16, ffts_per_round=1)
    res = fiveg.compare_barriers(
        KEY, app, radix=32, modes=("central", "tuned", "placed"))
    # the placed search space contains every tuned point (leaf_local
    # strategy x hierarchy-pruned compositions), so joint tuning can
    # only match or beat the schedule-only tuner up to draw noise
    assert float(res["speedup_placed"]) >= \
        float(res["speedup_tuned"]) - 0.05
    assert float(res["speedup_placed"]) > 1.4
    # scanned app == placement-aware unrolled oracle
    got = fiveg.simulate_app(KEY, app, sync="placed")
    ref = fiveg.simulate_app_reference(KEY, app, sync="placed")
    for name, a, b in zip(got._fields, got, ref):
        if isinstance(a, str):   # winning-schedule names, not timings
            assert a == b and a, name
            continue
        assert float(a) == pytest.approx(float(b), rel=1e-5), name


# ---------------------------------------------------------------------------
# Locality-class primitives behind the derivation.
# ---------------------------------------------------------------------------

def test_span_bank_latency_classes():
    cfg = DEFAULT
    # PEs 0..7 (tile 0): bank 0 is in-tile, bank 40 (tile 1) in-group,
    # bank 600 (group 1) cross-group.
    assert cfg.span_bank_latency(0, 8, 0) == cfg.lat_tile
    assert cfg.span_bank_latency(0, 8, 40) == cfg.lat_group
    assert cfg.span_bank_latency(0, 8, 600) == cfg.lat_cluster
    # spans crossing a tile can never be tile-class, even to bank 0
    assert cfg.span_bank_latency(0, 16, 0) == cfg.lat_group
    assert cfg.span_bank_latency(0, 256, 0) == cfg.lat_cluster
    assert cfg.pe_bank_latency(9, 36) == cfg.lat_tile   # PE 9, tile-1 bank
