"""End-to-end behaviour tests for the whole system."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint, configs, optim
from repro.data import DataConfig, batch_for_model
from repro.models import init_params, loss_fn
from repro.runtime import FaultConfig, FaultTolerantRunner

KEY = jax.random.PRNGKey(0)
REPO = Path(__file__).resolve().parent.parent


def test_end_to_end_training_reduces_loss(tmp_path):
    """Train a small qwen3-family model for 60 steps through the
    fault-tolerant runner: loss must drop substantially; a restart from
    checkpoint must continue, not regress."""
    cfg = configs.get_smoke("qwen3_4b")
    dcfg = DataConfig(seed=1, seq_len=64, global_batch=8,
                      vocab_size=cfg.vocab_size)
    ocfg = optim.OptConfig.from_model(cfg, lr=3e-3, warmup_steps=10,
                                      total_steps=120, weight_decay=0.01)
    params = init_params(cfg, KEY)
    opt_state = optim.init(params, ocfg)

    @jax.jit
    def train_step(state, batch):
        p, s = state
        (loss, metrics), grads = jax.value_and_grad(
            lambda q: loss_fn(q, cfg, batch), has_aux=True)(p)
        p2, s2 = optim.update(grads, s, p, ocfg)
        return (p2, s2), metrics

    def batch_fn(step):
        return jax.tree.map(jnp.asarray, batch_for_model(cfg, dcfg, step))

    losses = []
    runner = FaultTolerantRunner(
        FaultConfig(ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=25),
        step_fn=train_step, batch_fn=batch_fn,
        state_template=(params, opt_state))
    runner.run(60, on_step=lambda s: losses.append(s.metrics["loss"]))

    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.5, (first, last)

    # restart resumes from the checkpoint, loss stays near the tail
    runner2 = FaultTolerantRunner(
        FaultConfig(ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=25),
        step_fn=train_step, batch_fn=batch_fn,
        state_template=(params, opt_state))
    assert runner2.resume_step() == 60   # saved at 24, 49 and 59
    more = []
    runner2.run(65, on_step=lambda s: more.append(s.metrics["loss"]))
    assert np.mean(more) < first - 0.4


def test_distribution_suite_multidevice():
    """Re-run the sharded-step tests on an 8-device host platform."""
    env = dict(os.environ)
    env["REPRO_MULTIDEV"] = "1"
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-x",
         str(REPO / "tests" / "test_distribution.py")],
        env=env, capture_output=True, text=True, timeout=1800)
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-2000:]


def test_dryrun_cell_cli(tmp_path):
    """The dry-run CLI lowers+compiles one real cell on the production
    512-device multi-pod mesh (the MINIMUM multi-pod requirement)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen3-4b",
         "--shape", "decode_32k", "--mesh", "multi", "--out",
         str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-2000:]
    assert "ok" in r.stdout
