"""Batched arrival sampler API (`workloads.arrival_batch`): registry
coverage, shapes/dtype, key-determinism, and bit-for-bit equality of
the vmapped batch with a Python loop over split keys — for every
kernel (Fig. 5/6 suite + 5G epochs) at N in {64, 256}."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import workloads
from repro.core.topology import DEFAULT

KEY = jax.random.PRNGKey(0)


def test_registry_covers_suite_and_5g_epochs():
    assert len(workloads.FIG6_KERNELS) == 15          # 5 kernels x 3 inputs
    for kernel, dims in workloads.benchmark_suite().items():
        for label in dims:
            assert f"{kernel}_{label}" in workloads.FIG6_KERNELS
    assert workloads.ARRIVAL_KERNELS == workloads.FIG6_KERNELS + (
        "fiveg_fft_stage", "fiveg_matmul_row",
        "straggler_lognormal", "straggler_pareto")
    assert set(workloads.arrival_fns()) == set(workloads.ARRIVAL_KERNELS)


@pytest.mark.parametrize("n", [64, 256])
def test_arrival_batch_shapes_dtype_determinism(n):
    for kernel in workloads.ARRIVAL_KERNELS:
        a = workloads.arrival_batch(KEY, kernel, (3, n))
        assert a.shape == (3, n), kernel
        assert a.dtype == jnp.float32, kernel
        assert np.isfinite(np.asarray(a)).all(), kernel
        # same key -> same batch, bit for bit
        b = workloads.arrival_batch(KEY, kernel, (3, n))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=kernel)
        # distinct trials draw distinct arrivals
        assert float(jnp.max(jnp.abs(a[0] - a[1]))) > 0.0, kernel


@pytest.mark.parametrize("n", [64, 256])
def test_arrival_batch_matches_key_loop_bitforbit(n):
    """The batched (vmapped) sampler is the SAME program as stacking
    single-vector draws over ``jax.random.split`` keys — so workload
    sweeps tuned on batches agree exactly with per-trial replays."""
    cfg = dataclasses.replace(DEFAULT, n_pes=n)
    fns = workloads.arrival_fns(cfg)
    keys = jax.random.split(KEY, 4)
    for kernel in workloads.ARRIVAL_KERNELS:
        batched = workloads.arrival_batch(KEY, kernel, (4, n))
        looped = jnp.stack([fns[kernel](k) for k in keys])
        np.testing.assert_array_equal(np.asarray(batched),
                                      np.asarray(looped), err_msg=kernel)


def test_arrival_batch_validation():
    with pytest.raises(ValueError):
        workloads.arrival_batch(KEY, "not_a_kernel", (2, 64))
    with pytest.raises(ValueError):
        workloads.arrival_batch(KEY, "dotp_1Mi", (0, 64))


def test_straggler_samplers_heavy_tail():
    """The straggler epochs keep the AXPY-like bulk but grow a heavy
    right tail: max/median far beyond the fault-free scatter, Pareto
    bounded at 256x the base work."""
    n = 256
    work = (1 << 18) / n * workloads.COSTS.axpy_per_elem
    for kernel in ("straggler_lognormal", "straggler_pareto"):
        a = np.asarray(workloads.arrival_batch(KEY, kernel, (8, n)))
        med = np.median(a)
        assert abs(med - work) < 0.2 * work, kernel   # bulk ~ base work
        assert a.max() > 1.1 * med, kernel            # heavy tail
    p = np.asarray(workloads.arrival_batch(KEY, "straggler_pareto", (8, n)))
    assert p.max() <= 258.0 * work                    # bounded Pareto
    with pytest.raises(ValueError, match="unknown straggler tail"):
        workloads.straggler_arrivals(KEY, 1 << 18, tail="cauchy")
    with pytest.raises(ValueError, match="frac"):
        workloads.straggler_arrivals(KEY, 1 << 18, frac=0.0)


def test_pe_fault_model_apply():
    """apply_faults: zero model is a bitwise no-op; fail-stop masks to
    +inf at ~p_fail; stalls/straggles only ever delay arrivals."""
    arr = workloads.arrival_batch(KEY, "axpy_256Ki", (16, 256))
    same = workloads.apply_faults(KEY, arr)
    np.testing.assert_array_equal(np.asarray(arr), np.asarray(same))

    model = workloads.PEFaultModel(p_fail=0.1)
    failed = np.asarray(workloads.apply_faults(KEY, arr, model))
    rate = np.mean(~np.isfinite(failed))
    assert 0.05 < rate < 0.2
    np.testing.assert_array_equal(failed[np.isfinite(failed)],
                                  np.asarray(arr)[np.isfinite(failed)])

    slow = workloads.PEFaultModel(p_stall=0.3, stall_cycles=123.0,
                                  p_straggler=0.2)
    delayed = np.asarray(workloads.apply_faults(KEY, arr, slow))
    assert np.isfinite(delayed).all()
    assert (delayed >= np.asarray(arr)).all()
    assert (delayed > np.asarray(arr)).any()

    mask = np.asarray(workloads.fault_mask(KEY, 4096, 0.25))
    assert mask.dtype == bool and 0.15 < mask.mean() < 0.35
    with pytest.raises(ValueError, match="p_fail"):
        workloads.PEFaultModel(p_fail=1.5)


def test_fiveg_epoch_models_match_config():
    """The 5G epoch samplers reproduce the app simulator's work/jitter
    windows: stage arrivals live in [work, work + jitter), matmul-row
    arrivals in [mm_work, 1.05 * mm_work)."""
    from repro.core.fiveg import FiveGConfig
    app = FiveGConfig()
    a = np.asarray(workloads.arrival_batch(KEY, "fiveg_fft_stage",
                                           (4, 1024), app=app))
    assert a.min() >= app.epoch_work
    assert a.max() <= app.epoch_work + app.epoch_jitter
    m = np.asarray(workloads.arrival_batch(KEY, "fiveg_matmul_row",
                                           (4, 1024), app=app))
    assert m.min() >= app.mm_work(1024)
    assert m.max() <= app.mm_work(1024) + app.mm_jitter(1024)
