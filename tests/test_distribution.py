"""Distribution-layer tests on a multi-device CPU mesh.

Run standalone (forces 8 host devices): these tests self-skip when the
process was initialized with a single device, and pytest re-execs them
via a subprocess fixture in conftest.py when needed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.core import collectives
from repro.launch import mesh as mesh_mod, steps
from repro.models import ModelConfig, init_params

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs XLA_FLAGS device_count>=8")

KEY = jax.random.PRNGKey(0)


def _mesh():
    return mesh_mod._mk((2, 2, 2), ("pod", "data", "model"))


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=32,
                vocab_size=64, n_heads=4, n_kv_heads=2, d_ff=64,
                attn_chunk=16, micro_batches=2)
    base.update(kw)
    return ModelConfig(**base)


def _place(tree, plan, mesh):
    return jax.tree.map(
        lambda p, s: jax.device_put(p, NamedSharding(mesh, s)), tree,
        plan)


def _train_once(cfg, sync, mesh, tokens):
    with mesh_mod.mesh_context(mesh):
        fn, art = steps.build_train_step(cfg, mesh, sync=sync)
        params = _place(init_params(cfg, KEY), art["plan"].full, mesh)
        opt_state = jax.jit(
            lambda p: optim.init(p, art["opt_cfg"]),
            out_shardings=jax.tree.map(
                lambda s: NamedSharding(mesh, s), art["splan"].full)
        )(params)
        batch = jax.tree.map(
            lambda x: jax.device_put(
                x, NamedSharding(mesh, P(("pod", "data")))),
            {"tokens": tokens, "targets": tokens})
        p2, o2, m = fn(params, opt_state, batch)
        jax.block_until_ready(p2)
    return p2, m


def test_flat_and_hier_sync_agree():
    """Paper invariant: the synchronization schedule (central-counter vs
    tree) never changes the result — only its cost."""
    cfg = _cfg()
    mesh = _mesh()
    tokens = jax.random.randint(KEY, (8, 32), 0, 64)
    p_hier, m_hier = _train_once(cfg, collectives.HIERARCHICAL, mesh,
                                 tokens)
    p_flat, m_flat = _train_once(cfg, collectives.FLAT, mesh, tokens)
    assert m_hier["loss"] == pytest.approx(m_flat["loss"], rel=1e-4)
    for a, b in zip(jax.tree.leaves(p_hier), jax.tree.leaves(p_flat)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)


def test_sharded_matches_single_device():
    """The distributed step computes the same loss as an unsharded
    single-device step on the identical batch."""
    from repro.models import loss_fn
    cfg = _cfg(micro_batches=1)
    mesh = _mesh()
    tokens = jax.random.randint(KEY, (8, 32), 0, 64)
    _, m = _train_once(cfg, collectives.HIERARCHICAL, mesh, tokens)
    params = init_params(cfg, KEY)
    loss, _ = jax.jit(lambda p, b: loss_fn(p, cfg, b))(
        params, {"tokens": tokens, "targets": tokens})
    assert float(m["loss"]) == pytest.approx(float(loss), rel=5e-3)


def test_serve_prefill_decode_sharded():
    cfg = _cfg()
    mesh = _mesh()
    with mesh_mod.mesh_context(mesh):
        pre, art = steps.build_prefill_step(cfg, mesh, batch=8, seq_len=32)
        params = _place(init_params(cfg, KEY), art["plan"].full, mesh)
        tokens = jax.device_put(
            jax.random.randint(KEY, (8, 32), 0, 64),
            NamedSharding(mesh, P(("pod", "data"), None)))
        logits, caches = pre(params, {"tokens": tokens})
        assert logits.shape == (8, 1, 64)
        dec, dart = steps.build_decode_step(cfg, mesh, batch=8, max_len=32)
        # decode donates the cache: place it on the decode shardings
        caches = jax.device_put(caches, dart["cache_shardings"])
        tok = jax.device_put(jnp.zeros((8, 1), jnp.int32),
                             NamedSharding(mesh, P(("pod", "data"), None)))
        pos = jax.device_put(jnp.full((8,), 31, jnp.int32),
                             NamedSharding(mesh, P(("pod", "data"))))
        lg, caches2 = dec(params, caches, tok, pos)
        assert jnp.isfinite(np.asarray(lg, np.float32)).all()


@pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="jax < 0.5: experimental shard_map aborts XLA compiling the "
           "psum_scatter chain on the CPU backend")
def test_tree_psum_equals_flat_psum():
    """core.collectives.tree_psum == lax.psum under any radix split."""
    mesh = _mesh()

    def flat(x):
        return collectives.psum_chain(x, ("pod", "data"))

    def tree(x):
        return collectives.tree_psum(x, ("pod", "data"), scatter_dim=0)

    x = jnp.arange(64, dtype=jnp.float32).reshape(16, 4)
    outs = []
    for f in (flat, tree):
        g = collectives.shard_map_compat(f, mesh, P(("pod", "data")), P(),
                                         ("pod", "data"))
        outs.append(np.asarray(jax.jit(g)(x)))
    np.testing.assert_allclose(outs[0][:4], outs[1][:4], rtol=1e-6)


def test_factored_mesh_radix():
    m = collectives.make_factored_mesh(2, model=2, data=4)
    assert m.axis_names == ("data0", "data1", "model")
    assert m.shape["data0"] == 2 and m.shape["data1"] == 2
    with pytest.raises(ValueError):
        collectives.make_factored_mesh(3, model=2, data=4)


def test_factored_mesh_mixed_factors():
    """Mixed per-stage factors mirror barrier.mixed_radix_tree."""
    m = collectives.make_factored_mesh((4, 2), model=1, data=8)
    assert m.axis_names == ("data0", "data1", "model")
    assert m.shape["data0"] == 4 and m.shape["data1"] == 2
    with pytest.raises(ValueError):
        collectives.make_factored_mesh((4, 4), model=1, data=8)  # product
    with pytest.raises(ValueError):
        collectives.make_factored_mesh((4, 3), model=1, data=12)  # pow2
