"""Energy subsystem: per-event accounting bit-for-bit against the
independent numpy oracle, the hardware event-unit primitive (incl.
non-power-of-two machines), the 2-D latency x energy Pareto machinery,
and the one-compile property of energy-carrying grids."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (barrier, barrier_sim, energy, fiveg, placement,
                        sweep, tuning)
from repro.core.energy import DEFAULT_ENERGY, EnergyModel
from repro.core.placement import STRATEGIES
from repro.core.topology import DEFAULT, TeraPoolConfig, multi_cluster

KEY = jax.random.PRNGKey(0)

# A non-power-of-two cluster: 768 PEs as 8 x 12 x 8 (12-Tile Groups),
# and its 2-cluster scale-out (1536 PEs, remote tier).
C768 = TeraPoolConfig(n_pes=768, tiles_per_group=12, n_groups=8)
C1536 = multi_cluster(C768, n_clusters=2)


def _cfg(n: int) -> TeraPoolConfig:
    return DEFAULT if n == DEFAULT.n_pes else TeraPoolConfig(n_pes=n)


def _sample_schedules(n: int, cfg):
    """Per-class representatives of the composition space: the central
    counter, a flat-ish tree, the binary chain, the hierarchy-matched
    mixed tree."""
    scheds = [barrier.central_counter(n_pes=n, cfg=cfg),
              barrier.kary_tree(min(32, n), n_pes=n, cfg=cfg),
              barrier.kary_tree(2, n_pes=n, cfg=cfg),
              barrier.kary_tree(8, n_pes=n, cfg=cfg)]
    mixed = {64: (8, 8), 256: (8, 16, 2), 1024: (8, 16, 8)}[n]
    scheds.append(barrier.mixed_radix_tree(mixed, cfg=cfg))
    return scheds


# ---------------------------------------------------------------------------
# Bit-for-bit vs the independent numpy oracle.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [64, 256, 1024])
def test_energy_matches_numpy_oracle_compositions(n):
    """Both JAX cores AND the eager reference produce the numpy
    oracle's energy exactly — float equality, not approx — for every
    sampled composition; at N=64 the FULL exhaustive space."""
    cfg = _cfg(n)
    scheds = (tuning.all_schedules(n, cfg) if n == 64
              else _sample_schedules(n, cfg))
    arr = 300.0 * jax.random.uniform(KEY, (2, n))
    for sched in scheds:
        want = np.asarray(energy.energy_reference(arr, sched, cfg))
        for core in ("telescope", "scan"):
            got = barrier_sim.simulate(arr, sched, cfg, core=core).energy
            np.testing.assert_array_equal(
                np.asarray(got), want, err_msg=f"{sched.sizes} {core}")
        ref = barrier_sim.simulate_reference(arr, sched, cfg).energy
        np.testing.assert_array_equal(np.asarray(ref), want,
                                      err_msg=f"{sched.sizes} eager-ref")


@pytest.mark.parametrize("n", [64, 256, 1024])
def test_energy_matches_numpy_oracle_placements(n):
    """Placement-aware energy (per-counter latencies priced per hop,
    per-BANK queue exits) is bit-for-bit the numpy oracle's for every
    named strategy."""
    cfg = _cfg(n)
    sched = barrier.kary_tree(8, n_pes=n, cfg=cfg)
    arr = 300.0 * jax.random.uniform(KEY, (2, n))
    for strat in STRATEGIES:
        plc = placement.place_counters(sched, strat, cfg)
        want = np.asarray(
            energy.energy_reference(arr, sched, cfg, placement=plc))
        for core in ("telescope", "scan"):
            got = barrier_sim.simulate(arr, sched, cfg, placement=plc,
                                       core=core).energy
            np.testing.assert_array_equal(
                np.asarray(got), want, err_msg=f"{strat} {core}")
        ref = placement.simulate_placed_reference(arr, sched, plc,
                                                  cfg).energy
        np.testing.assert_array_equal(np.asarray(ref), want,
                                      err_msg=f"{strat} placed-ref")


def test_count_events_matches_closed_form():
    """The deliberately-dumb counting loops and the closed-form
    constants agree on every sampled schedule x placement."""
    for n in (64, 1024):
        cfg = _cfg(n)
        for sched in _sample_schedules(n, cfg):
            for plc in [None, placement.place_counters(
                    sched, "leaf_local", cfg)]:
                stat, act, idle = energy.schedule_energy_constants(
                    sched, plc, cfg)
                stat2, act2 = energy._count_events(sched, plc, cfg,
                                                   DEFAULT_ENERGY)
                assert float(stat) == float(stat2)
                assert float(act) == float(act2)
                assert float(idle) == float(
                    np.float32(DEFAULT_ENERGY.idle_power))
        hw = barrier.hw_event_unit(cfg=cfg)
        stat, act, _ = energy.schedule_energy_constants(hw, None, cfg)
        stat2, act2 = energy._count_events(hw, None, cfg, DEFAULT_ENERGY)
        assert (float(stat), float(act)) == (float(stat2), float(act2))


# ---------------------------------------------------------------------------
# Hardware event unit: structure + exactness, incl. non-power-of-two.
# ---------------------------------------------------------------------------

def test_hw_event_unit_structure():
    s = barrier.hw_event_unit(cfg=DEFAULT)
    assert s.hw and s.n_pes == 1024
    assert s.sizes == (8, 16, 8)          # Tile / Group / cluster stages
    assert all(lvl.latency == DEFAULT.hw_level_cycles for lvl in s.levels)
    assert barrier.schedule_name(s) == "hw8x16x8"
    assert "hardware event unit" in barrier.describe(s)
    # the remote tier of a multi-cluster machine costs lat_remote
    s2 = barrier.hw_event_unit(cfg=C1536)
    assert s2.sizes[-1] == 2
    assert s2.levels[-1].latency == C1536.lat_remote
    with pytest.raises(ValueError):
        barrier.level_table(
            barrier.hw_event_unit(cfg=DEFAULT), cfg=DEFAULT,
            placement=placement.place_counters(
                barrier.kary_tree(8), "leaf_local", DEFAULT))


@pytest.mark.parametrize("cfg", [C768, C1536],
                         ids=["N768", "N1536-2cluster"])
def test_hw_exact_nonpow2(cfg):
    """hw primitive at non-power-of-two N: both cores == eager
    reference == numpy oracle, every field, bit for bit."""
    sched = barrier.hw_event_unit(cfg=cfg)
    arr = 200.0 * jax.random.uniform(KEY, (2, cfg.n_pes))
    ref = barrier_sim.simulate_reference(arr, sched, cfg)
    for core in ("telescope", "scan"):
        got = barrier_sim.simulate(arr, sched, cfg, core=core)
        for name, a, b in zip(got._fields, got, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{core}: {name}")
    np.testing.assert_array_equal(
        np.asarray(ref.energy),
        np.asarray(energy.energy_reference(arr, sched, cfg)))


@pytest.mark.parametrize("n", [256, 1024])
def test_hw_dominates_software(n):
    """Glaser et al.'s qualitative headline on TeraPool: the event-unit
    barrier beats EVERY software design on both cycles and energy."""
    cfg = _cfg(n)
    scheds = _sample_schedules(n, cfg)
    res = sweep.sweep_schedules(KEY, scheds, delays=(0.0, 200.0),
                                n_trials=8, cfg=cfg)
    hw = sweep.sweep_schedules(KEY, [barrier.hw_event_unit(cfg=cfg)],
                               delays=(0.0, 200.0), n_trials=8, cfg=cfg)
    assert float(jnp.max(hw.mean_span)) < float(jnp.min(res.mean_span))
    assert float(jnp.max(hw.mean_energy)) < float(jnp.min(res.mean_energy))


# ---------------------------------------------------------------------------
# 2-D latency x energy Pareto machinery.
# ---------------------------------------------------------------------------

def test_pareto_front_n1024_acceptance():
    """The acceptance-criterion front: the exhaustive N=1024 space at
    simultaneous arrival holds >= 3 mutually non-dominated software
    designs (deep trees win cycles, wide trees win energy), the 1-D
    best-by-cycles point leads the front, and the hw point dominates
    all of it."""
    res = tuning.tune_barrier(
        KEY, 1024, delays=(0.0,), n_trials=4, cfg=DEFAULT,
        schedules=tuning.all_schedules(1024, DEFAULT, prune="none"))
    front = tuning.pareto_front(res)
    assert len(front) >= 3
    # sorted fastest-first; the head is the 1-D best-by-cycles winner
    spans = np.asarray(jnp.mean(res.span_cycles, axis=-1))[:, 0]
    assert front[0].mean_span == pytest.approx(float(spans.min()))
    # mutually non-dominated: energy strictly decreases as span grows
    for a, b in zip(front, front[1:]):
        assert a.mean_span < b.mean_span
        assert a.mean_energy > b.mean_energy
    # the hw point dominates the entire software front
    hw = sweep.sweep_schedules(KEY, [barrier.hw_event_unit(cfg=DEFAULT)],
                               delays=(0.0,), n_trials=4, cfg=DEFAULT)
    hw_span = float(hw.mean_span[0, 0])
    hw_energy = float(hw.mean_energy[0, 0])
    assert all(hw_span < p.mean_span and hw_energy < p.mean_energy
               for p in front)
    # the generalized pareto_schedules front keeps a best-by-cycles
    # schedule (span ties CAN drop out of the 2-D front: of two
    # equal-span designs the higher-energy one is now dominated)
    both = tuning.pareto_schedules(res, objectives=("cycles", "energy"))
    ids = {id(s) for s in both}
    kept = [spans[i] for i, s in enumerate(res.schedules) if id(s) in ids]
    assert min(kept) == pytest.approx(float(spans.min()))


def test_objective_selectors():
    key = jax.random.PRNGKey(3)
    res = tuning.tune_barrier(key, 64, delays=(0.0,), n_trials=4)
    sp = jnp.mean(res.span_cycles, axis=-1)[:, 0]
    en = jnp.mean(res.energy, axis=-1)[:, 0]
    by_cycles = tuning.best_schedule(key, 64, n_trials=4)
    by_energy = tuning.best_schedule(key, 64, n_trials=4,
                                     objective="energy")
    by_edp = tuning.best_schedule(key, 64, n_trials=4, objective="edp")
    assert by_cycles.sizes == res.schedules[int(jnp.argmin(sp))].sizes
    assert by_energy.sizes == res.schedules[int(jnp.argmin(en))].sizes
    assert by_edp.sizes == res.schedules[int(jnp.argmin(sp * en))].sizes
    with pytest.raises(ValueError):
        tuning.best_schedule(key, 64, n_trials=4, objective="watts")
    with pytest.raises(ValueError):
        tuning.pareto_schedules(res, objectives=("cycles", "watts"))


def test_hw_in_tuned_stack_once_without_placement():
    """Crossing placements over a stack that includes the event unit
    keeps exactly ONE hw entry (the strategy axis is meaningless for a
    counterless barrier) with no placement attached."""
    scheds = [barrier.kary_tree(8, n_pes=64), barrier.hw_event_unit(64)]
    res = tuning.tune_barrier(KEY, 64, delays=(0.0,), n_trials=4,
                              schedules=scheds,
                              placements=("leaf_local", "group_hub"))
    hw_rows = [i for i, s in enumerate(res.schedules) if s.hw]
    assert len(hw_rows) == 1
    assert res.placements[hw_rows[0]] is None


# ---------------------------------------------------------------------------
# One-compile property of energy-carrying grids; model swap != retrace.
# ---------------------------------------------------------------------------

def test_energy_grid_compiles_once():
    """A sweep grid whose energy column is consumed — software trees
    AND the hw primitive stacked together — traces the core exactly
    once, and swapping the EnergyModel reuses the compiled program
    (the constants are traced table data)."""
    jax.clear_caches()
    barrier_sim.TRACE_COUNTS.clear()
    scheds = [barrier.kary_tree(r) for r in (4, 32)] \
        + [barrier.mixed_radix_tree((8, 16, 8)),
           barrier.hw_event_unit(cfg=DEFAULT)]
    res = sweep.sweep_schedules(KEY, scheds, delays=(0.0, 128.0),
                                n_trials=4)
    jax.block_until_ready(res.energy)
    assert res.energy.shape == (4, 2, 4)
    assert barrier_sim.core_traces() == 1

    # Same shapes under a different cost model: still no new trace,
    # different energy values.
    arr = 100.0 * jax.random.uniform(KEY, (1024,))
    e1 = barrier_sim.simulate(arr, scheds[0])
    hot = dataclasses.replace(DEFAULT_ENERGY, e_amo_issue=99.0,
                              p_wfi=0.4)
    e2 = barrier_sim.simulate(arr, scheds[0], energy_model=hot)
    jax.block_until_ready((e1.energy, e2.energy))
    assert barrier_sim.core_traces() == 2  # one batched-episode trace
    assert float(e2.energy) > float(e1.energy)
    e3 = barrier_sim.simulate(
        arr, scheds[0],
        energy_model=dataclasses.replace(DEFAULT_ENERGY, sleep="poll"))
    jax.block_until_ready(e3.energy)
    assert barrier_sim.core_traces() == 2  # still no retrace
    assert float(e3.energy) > float(e1.energy)  # polling burns more


def test_sweep_arrivals_carries_energy():
    scheds = [barrier.kary_tree(8, n_pes=64), barrier.hw_event_unit(64)]
    arr = 100.0 * jax.random.uniform(KEY, (3, 5, 64))
    cfg = _cfg(64)
    res = sweep.sweep_arrivals(arr, scheds, cfg=cfg)
    assert res.energy.shape == (2, 3, 5)
    assert res.mean_energy.shape == (2, 3)
    want = barrier_sim.simulate(arr[1], scheds[0], cfg).energy
    np.testing.assert_array_equal(np.asarray(res.energy[0, 1]),
                                  np.asarray(want))


# ---------------------------------------------------------------------------
# Model validation + cache codecs.
# ---------------------------------------------------------------------------

def test_energy_model_validation():
    assert EnergyModel(sleep="poll").idle_power == EnergyModel().p_poll
    with pytest.raises(ValueError):
        EnergyModel(sleep="nap").idle_power
    with pytest.raises(ValueError):
        energy.schedule_energy_constants(
            barrier.hw_event_unit(cfg=DEFAULT),
            placement.place_counters(barrier.kary_tree(8), "leaf_local",
                                     DEFAULT))


def test_schedule_cache_hw_and_objective_roundtrip():
    from repro.runtime import schedule_cache
    hw = barrier.hw_event_unit(cfg=DEFAULT)
    dec = schedule_cache.decode_schedule(
        schedule_cache.encode_schedule(hw), DEFAULT)
    assert dec.hw and dec.sizes == hw.sizes and dec.n_pes == hw.n_pes
    sw = barrier.kary_tree(8)
    dec_sw = schedule_cache.decode_schedule(
        schedule_cache.encode_schedule(sw), DEFAULT)
    assert not dec_sw.hw and dec_sw.sizes == sw.sizes
    pair = schedule_cache.encode_pair(sw, None, objective="pareto")
    assert schedule_cache.pair_objective(pair) == "pareto"
    # legacy entries written before the energy subsystem lack the field
    legacy = {"schedule": schedule_cache.encode_schedule(sw),
              "placement": None}
    assert schedule_cache.pair_objective(legacy) == "cycles"
    assert schedule_cache.decode_pair(legacy, DEFAULT)[1] is None


# ---------------------------------------------------------------------------
# 5G application energy.
# ---------------------------------------------------------------------------

def test_fiveg_hw_parity_and_energy():
    """sync="hw" through the scanned app core == the unrolled eager
    reference, and the energy columns order as Glaser predicts."""
    app = fiveg.FiveGConfig(n_rx=8, ffts_per_round=2)
    got = fiveg.simulate_app(KEY, app, sync="hw")
    ref = fiveg.simulate_app_reference(KEY, app, sync="hw")
    for name, a, b in zip(got._fields, got, ref):
        if isinstance(a, str):
            assert a == b, name
        else:
            assert float(a) == pytest.approx(float(b), rel=1e-6), name
    assert got.stage_schedule == "hw8x16x8"
    central = fiveg.simulate_app(KEY, app, sync="central")
    assert float(got.sync_energy) < float(central.sync_energy)
    assert float(got.energy_fraction) < float(central.energy_fraction)
    assert 0.0 < float(got.energy_fraction) < 1.0
    assert float(got.total_energy) > float(got.sync_energy)


def test_fiveg_compare_barriers_energy_ratios():
    out = fiveg.compare_barriers(KEY, app=fiveg.FiveGConfig(
        n_rx=8, ffts_per_round=2), modes=("central", "tree", "hw"))
    assert float(out["energy_ratio_hw"]) > 1.0
    assert float(out["energy_ratio_hw"]) > float(out["energy_ratio_tree"])
    assert float(out["speedup_hw"]) > 1.0
