"""Per-architecture smoke tests: reduced same-family configs run one
forward/train step on CPU; shapes + finiteness asserted.  The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, optim
from repro.data import DataConfig, batch_for_model
from repro.models import (applicable_shapes, forward, init_caches,
                          init_params, loss_fn)

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    d = batch_for_model(cfg, DataConfig(seq_len=S, global_batch=B,
                                        vocab_size=cfg.vocab_size), 0)
    return jax.tree.map(jnp.asarray, d)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get_smoke(arch)
    params = init_params(cfg, KEY)
    batch = _batch(cfg)

    loss, metrics = jax.jit(lambda p, b: loss_fn(p, cfg, b))(params, batch)
    assert jnp.isfinite(loss), (arch, metrics)
    assert 0 < float(loss) < 20

    # one optimizer step decreases nothing catastrophic & stays finite
    ocfg = optim.OptConfig.from_model(cfg, lr=1e-3)
    state = optim.init(params, ocfg)

    def step(p, s, b):
        grads, _ = jax.grad(lambda q: loss_fn(q, cfg, b), has_aux=True)(p)
        return optim.update(grads, s, p, ocfg)

    p2, s2 = jax.jit(step)(params, state, batch)
    for leaf in jax.tree.leaves(p2):
        assert jnp.isfinite(leaf.astype(jnp.float32)).all(), arch
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved, arch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_decode_matches_full_forward(arch):
    import dataclasses
    cfg = configs.get_smoke(arch)
    if cfg.family == "encoder":
        pytest.skip("encoder-only: no decode")
    if cfg.is_moe:
        # Two discrete routing decisions legitimately differ between
        # batched prefill and single-token decode and would turn tiny
        # bf16 accumulation-order differences (e.g. MLA's absorbed
        # decode path reorders the attention matmuls) into full expert
        # swaps: capacity dropping (tokens compete for slots) and the
        # top-k selection itself (near-tied router probs flip).
        # Neutralize both for the numerical-equivalence check: no-drop
        # capacity, and every expert selected (gates still weight by
        # router probability, so the check stays end-to-end).
        cfg = dataclasses.replace(cfg, capacity_factor=8.0,
                                  top_k=cfg.n_experts)
    params = init_params(cfg, KEY)
    B, S = 2, 32
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.frontend == "vision":
        batch["img_embeds"] = jnp.zeros((B, cfg.n_frontend_tokens,
                                         cfg.d_model), jnp.bfloat16)
    full, _, _, _ = forward(params, cfg, batch, remat=False)

    caches = init_caches(cfg, B, S)
    pre = dict(batch)
    pre["tokens"] = tokens[:, :-1]
    _, caches, _, _ = forward(params, cfg, pre, caches=caches, remat=False)
    lg, _, _, _ = forward(params, cfg, {"tokens": tokens[:, -1:]},
                          caches=caches,
                          decode_pos=jnp.full((B,), S - 1, jnp.int32),
                          remat=False)
    a = np.asarray(lg[:, 0], np.float32)
    b = np.asarray(full[:, -1], np.float32)
    # bf16 accumulation-order tolerance
    assert np.max(np.abs(a - b)) < 0.35, (arch, np.max(np.abs(a - b)))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_shape_cell_applicability(arch):
    cfg = configs.get(arch)
    names = {s.name for s in applicable_shapes(cfg)}
    if cfg.family == "encoder":
        assert names == {"train_4k", "prefill_32k"}
    elif cfg.has_ssm or cfg.attn_window:
        assert names == {"train_4k", "prefill_32k", "decode_32k",
                         "long_500k"}
    else:
        assert names == {"train_4k", "prefill_32k", "decode_32k"}


def test_param_counts_match_published():
    expected = {
        "qwen3_4b": (4.0, 4.9),
        "nemotron_4_340b": (330, 350),
        "codeqwen15_7b": (7, 9),
        "yi_34b": (33, 36),
        "internvl2_76b": (68, 78),
        "hymba_1_5b": (1.4, 1.9),
        "hubert_xlarge": (0.9, 1.4),
        "falcon_mamba_7b": (6.8, 7.8),
        "deepseek_v3_671b": (665, 680),
    }
    for arch, (lo, hi) in expected.items():
        n = configs.get(arch).param_count() / 1e9
        assert lo <= n <= hi, (arch, n)
    active = configs.get("deepseek_v3_671b").active_param_count() / 1e9
    assert 35 <= active <= 40, active
