"""Multi-cluster TeraPool-of-TeraPools: the remote latency tier of
:class:`~repro.core.topology.MultiClusterConfig`, the generalized
(non-power-of-two, hierarchical) schedule algebra and telescope width
tables, bit-for-bit telescope == scan equivalence across hierarchical
and non-power-of-two compositions x placements, the one-compile
property of multi-cluster grids, and the 2-D (schedule x kernel)
sweep-sharding machinery."""
import math
import os
import random
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import barrier, barrier_sim, placement, sweep, tuning
from repro.core.topology import (DEFAULT, MultiClusterConfig,
                                 TeraPoolConfig, multi_cluster)
from repro.runtime import elastic

KEY = jax.random.PRNGKey(0)
REPO = Path(__file__).resolve().parent.parent

# A non-power-of-two cluster: 768 PEs as 8 x 12 x 8 (12-Tile Groups).
C768 = TeraPoolConfig(n_pes=768, tiles_per_group=12, n_groups=8)


def _assert_bitwise(got, want, ctx):
    for name, a, b in zip(got._fields, got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{ctx}: {name}")


def _random_factorization(rng: random.Random, n: int) -> tuple:
    """A uniformly drawn ordered factorization of ``n`` into sizes >= 2."""
    sizes = []
    while n > 1:
        f = rng.choice([d for d in range(2, n + 1) if n % d == 0])
        sizes.append(f)
        n //= f
    return tuple(sizes)


# ---------------------------------------------------------------------------
# MultiClusterConfig: the remote latency tier and its placement classes.
# ---------------------------------------------------------------------------

def test_multi_cluster_factory_and_shape():
    cfg = multi_cluster(TeraPoolConfig(n_pes=1024), n_clusters=4)
    assert cfg.n_pes == 4096
    assert cfg.pes_per_cluster == 1024
    assert cfg.banks_per_cluster == 4096
    assert cfg.n_banks == 16384
    # per-cluster timing fields carry over from the wrapped cluster
    assert cfg.pes_per_tile == 8 and cfg.lat_tile == 1


def test_multi_cluster_nonpow2_cluster():
    cfg = multi_cluster(C768, n_clusters=2, lat_remote=31)
    assert cfg.n_pes == 1536
    assert cfg.pes_per_cluster == 768
    assert cfg.lat_remote == 31


def test_multi_cluster_config_validates():
    with pytest.raises(ValueError, match="cluster"):
        MultiClusterConfig(n_pes=1024, n_clusters=0)
    with pytest.raises(ValueError, match="split"):
        MultiClusterConfig(n_pes=1000, n_clusters=3)


def test_remote_latency_classes():
    cfg = multi_cluster(TeraPoolConfig(n_pes=1024), n_clusters=4)
    # intra-cluster accesses keep the Tile/Group/cluster classes
    assert cfg.span_bank_latency(0, 8, 0) == cfg.lat_tile
    assert cfg.span_bank_latency(0, 128, 0) == cfg.lat_group
    assert cfg.span_bank_latency(0, 1024, 0) == cfg.lat_cluster
    # a span crossing a cluster boundary is remote-class
    assert cfg.span_bank_latency(0, 2048, 0) == cfg.lat_remote
    # a bank in another cluster is remote even for a 1-PE span
    assert cfg.pe_bank_latency(1024, 0) == cfg.lat_remote
    assert cfg.pe_bank_latency(0, cfg.banks_per_cluster) == cfg.lat_remote
    # second cluster's local accesses are local again
    assert cfg.span_bank_latency(1024, 8, cfg.banks_per_cluster) == \
        cfg.lat_tile
    # span heuristic: whole-machine span is remote-class
    assert cfg.access_latency(cfg.n_pes) == cfg.lat_remote
    assert cfg.access_latency(1024) == cfg.lat_cluster


# ---------------------------------------------------------------------------
# Non-power-of-two schedule algebra.
# ---------------------------------------------------------------------------

def test_kary_tree_nonpow2():
    s = barrier.kary_tree(8, n_pes=768, cfg=C768)
    assert s.sizes == (12, 8, 8)
    assert math.prod(s.sizes) == 768
    s3 = barrier.kary_tree(4, n_pes=768, cfg=C768)
    assert s3.sizes == (3, 4, 4, 4, 4)
    with pytest.raises(ValueError, match="does not divide"):
        barrier.kary_tree(7, n_pes=768, cfg=C768)


def test_kary_tree_pow2_unchanged():
    # the generalized exponent formula reproduces the pow2 shapes
    assert barrier.kary_tree(8, n_pes=1024).sizes == (2, 8, 8, 8)
    assert barrier.kary_tree(4, n_pes=64).sizes == (4, 4, 4)
    assert barrier.kary_tree(1024, n_pes=1024).sizes == (1024,)


def test_all_radices_nonpow2():
    assert barrier.all_radices(768, C768) == \
        [k for k in range(2, 769) if 768 % k == 0]
    # pow2 list unchanged
    assert barrier.all_radices(64, DEFAULT) == [2, 4, 8, 16, 32, 64]


def test_enumerate_compositions_nonpow2():
    comps = tuning.enumerate_compositions(12, DEFAULT)
    assert (2, 2, 3) in comps and (12,) in comps and (3, 4) in comps
    assert all(math.prod(c) == 12 for c in comps)
    assert len(set(comps)) == len(comps)
    with pytest.raises(ValueError, match=">= 2"):
        tuning.enumerate_compositions(1, DEFAULT)


def test_hierarchy_compositions_nonpow2_and_multicluster():
    assert tuning._hier_segments(768, C768) == [8, 12, 8]
    comps = tuning.hierarchy_compositions(768, C768)
    assert all(math.prod(c) == 768 for c in comps)
    # multi-cluster machines peel the cluster count as the top segment
    mc = multi_cluster(TeraPoolConfig(n_pes=1024), n_clusters=4)
    assert tuning._hier_segments(4096, mc) == [8, 16, 8, 4]
    # intra-cluster sizes keep the single-cluster segments
    assert tuning._hier_segments(1024, mc) == [8, 16, 8]


def test_multicluster_schedule_space():
    mc = multi_cluster(TeraPoolConfig(n_pes=64), n_clusters=4)
    comps = tuning.multicluster_compositions(mc)
    assert all(math.prod(c) == 256 for c in comps)
    # joint product: intra space x inter space
    intra = tuning.hierarchy_compositions(64, mc)
    inter = tuning.enumerate_compositions(4, mc)
    assert len(comps) == len(intra) * len(inter)
    scheds = tuning.multicluster_schedules(mc)
    assert all(s.n_pes == 256 for s in scheds)


def test_mixed_radix_tree_nonpow2_levels():
    s = barrier.mixed_radix_tree((12, 8, 8), n_pes=768, cfg=C768)
    assert [l.group_size for l in s.levels] == [12, 8, 8]
    assert [l.span for l in s.levels] == [12, 96, 768]


# ---------------------------------------------------------------------------
# Generalized telescope widths.
# ---------------------------------------------------------------------------

def test_telescope_widths_cumulative_quotient():
    s = barrier.mixed_radix_tree((8, 16, 8, 4), cfg=multi_cluster(
        TeraPoolConfig(n_pes=1024), n_clusters=4))
    cfg = multi_cluster(TeraPoolConfig(n_pes=1024), n_clusters=4)
    t = barrier.level_table(s, cfg=cfg)
    w = barrier.telescope_widths(t, 4096)
    assert w[0] == 4096
    assert w[1] == 4096 // 8
    assert w[2] == 4096 // (8 * 16)
    assert w[3] == 4096 // (8 * 16 * 8)
    # padding tail keeps width 1
    assert all(x == 1 for x in w[4:])
    # non-increasing, and far tighter than the pow2 fallback
    assert all(a >= b for a, b in zip(w, w[1:]))
    assert sum(w) < sum(barrier.default_widths(4096, len(w) - 1))


def test_telescope_widths_stacked_max():
    cfg = DEFAULT
    scheds = [barrier.mixed_radix_tree((2,) * 10, cfg=cfg),
              barrier.mixed_radix_tree((1024,), cfg=cfg)]
    t = barrier.stack_tables(scheds, cfg)
    w = barrier.telescope_widths(t, 1024)
    # the radix-2 row dominates: exactly the pow2 fallback
    assert w == barrier.default_widths(1024, len(w) - 1)


def test_default_widths_nonpow2_bound():
    # floor-of-halving stays a valid upper bound for non-pow2 N
    for n in (768, 1536, 3072):
        cfg = C768 if n == 768 else multi_cluster(C768,
                                                  n_clusters=n // 768)
        sched = barrier.mixed_radix_tree(
            _random_factorization(random.Random(n), n), n_pes=n, cfg=cfg)
        t = barrier.level_table(sched, cfg=cfg)
        tight = barrier.telescope_widths(t, n)
        loose = barrier.default_widths(n, len(tight) - 1)
        assert all(a <= b for a, b in zip(tight, loose))


def test_telescope_rejects_short_widths():
    t = barrier.level_table(barrier.kary_tree(8, n_pes=64))
    with pytest.raises(ValueError, match="widths"):
        barrier_sim._telescope_core(jnp.zeros((64,)), t, DEFAULT,
                                    widths=(64, 8))


# ---------------------------------------------------------------------------
# validate_tail_padding diagnostics name the offending row/level.
# ---------------------------------------------------------------------------

def test_validate_tail_padding_reports_row_and_level():
    t = barrier.level_table(barrier.kary_tree(2, n_pes=64))
    bad = t._replace(
        group_sizes=jnp.asarray([2, 1, 2, 2, 2, 4], jnp.int32))
    with pytest.raises(ValueError, match=r"row 0 .*level 1"):
        barrier.validate_tail_padding(bad)


def test_validate_tail_padding_reports_padding_level():
    t = barrier.level_table(barrier.kary_tree(8, n_pes=64))
    bad = t._replace(instr_cycles=t.instr_cycles.at[-1].set(3.0))
    depth = t.group_sizes.shape[-1]
    with pytest.raises(ValueError,
                       match=rf"row 0, padding level {depth - 1}"):
        barrier.validate_tail_padding(bad)


def test_validate_tail_padding_accepts_nonpow2_tables():
    for comp in ((12, 8, 8), (768,), (2, 2, 2, 2, 48)):
        s = barrier.mixed_radix_tree(comp, n_pes=768, cfg=C768)
        t = barrier.level_table(s, cfg=C768)
        assert barrier.validate_tail_padding(t) is t
    stack = barrier.stack_tables(
        [barrier.mixed_radix_tree(c, n_pes=768, cfg=C768)
         for c in ((12, 8, 8), (768,), (2, 384))], C768)
    assert barrier.validate_tail_padding(stack) is stack


# ---------------------------------------------------------------------------
# Bit-for-bit equivalence: telescope == scan at hierarchical and
# non-power-of-two compositions x placements (the tentpole invariant).
# ---------------------------------------------------------------------------

def _machine(n_pes):
    if n_pes == 768:
        return C768
    if n_pes == 1024:
        return TeraPoolConfig(n_pes=1024)
    return multi_cluster(TeraPoolConfig(n_pes=1024),
                         n_clusters=n_pes // 1024)


def _stack_for(n_pes, cfg):
    if isinstance(cfg, MultiClusterConfig):
        scheds = tuning.multicluster_schedules(cfg)
        # keep the 4096-PE stacks bounded: every inter-cluster tree,
        # a spread of intra shapes
        if len(scheds) > 24:
            scheds = scheds[:: max(1, len(scheds) // 24)]
        return scheds
    return tuning.all_schedules(n_pes, cfg, prune="hierarchy")


@pytest.mark.parametrize("n_pes", [768, 1024, 2048, 4096])
def test_telescope_matches_scan_hierarchical(n_pes):
    cfg = _machine(n_pes)
    scheds = _stack_for(n_pes, cfg)
    arr = 512.0 * jax.random.uniform(KEY, (n_pes,))
    tele = sweep.simulate_schedules(arr, scheds, cfg, core="telescope")
    scan = sweep.simulate_schedules(arr, scheds, cfg, core="scan")
    _assert_bitwise(tele, scan, f"N={n_pes} ({type(cfg).__name__})")


@pytest.mark.parametrize("n_pes", [768, 2048])
def test_telescope_matches_scan_hierarchical_placed(n_pes):
    cfg = _machine(n_pes)
    scheds = _stack_for(n_pes, cfg)[:6]
    scheds, placs = tuning._cross_placements(
        scheds, placement.STRATEGIES, cfg)
    arr = 300.0 * jax.random.uniform(jax.random.PRNGKey(7), (n_pes,))
    tele = sweep.simulate_schedules(arr, scheds, cfg, placements=placs,
                                    core="telescope")
    scan = sweep.simulate_schedules(arr, scheds, cfg, placements=placs,
                                    core="scan")
    _assert_bitwise(tele, scan, f"N={n_pes} placed")


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1),
       st.sampled_from([768, 1536, 3072]),
       st.sampled_from([None, "leaf_local", "tile_interleaved",
                        "group_hub", "central"]),
       st.floats(0.0, 4096.0))
def test_random_nonpow2_composition_equivalence(seed, n_pes, strat,
                                                delay):
    """Property suite: random NON-power-of-two ordered factorization,
    random placement, random scatter — telescope must agree bit for
    bit with the full-width scan oracle."""
    cfg = (C768 if n_pes == 768
           else multi_cluster(C768, n_clusters=n_pes // 768))
    rng = random.Random(seed)
    sched = barrier.mixed_radix_tree(_random_factorization(rng, n_pes),
                                     n_pes=n_pes, cfg=cfg)
    plc = (None if strat is None
           else placement.place_counters(sched, strat, cfg))
    arr = delay * jax.random.uniform(jax.random.PRNGKey(seed), (n_pes,))
    tele = barrier_sim.simulate(arr, sched, cfg=cfg, placement=plc,
                                core="telescope")
    scan = barrier_sim.simulate(arr, sched, cfg=cfg, placement=plc,
                                core="scan")
    _assert_bitwise(tele, scan, (n_pes, sched.name, strat,
                                 round(delay, 1)))


def test_remote_tier_shows_in_simulation():
    """A cluster-straddling central counter must cost more than the
    hierarchy-aligned tree under the same arrivals (the latency tier
    actually reaches the simulated cycles)."""
    cfg = multi_cluster(TeraPoolConfig(n_pes=64), n_clusters=4)
    arr = jnp.zeros((256,))
    hier = barrier_sim.simulate(
        arr, barrier.mixed_radix_tree((8, 8, 4), cfg=cfg), cfg=cfg)
    flat = barrier_sim.simulate(
        arr, barrier.mixed_radix_tree((256,), cfg=cfg), cfg=cfg)
    assert float(flat.span_cycles) > float(hier.span_cycles)


# ---------------------------------------------------------------------------
# One-compile property across a full multi-cluster grid.
# ---------------------------------------------------------------------------

def test_multicluster_grid_one_compile():
    cfg = multi_cluster(TeraPoolConfig(n_pes=64), n_clusters=4)
    scheds = tuning.multicluster_schedules(cfg)
    jax.clear_caches()
    barrier_sim.TRACE_COUNTS.clear()
    res = sweep.sweep_schedules(jax.random.PRNGKey(3), scheds,
                                delays=(0.0, 128.0, 2048.0), n_trials=4,
                                cfg=cfg, core="telescope")
    jax.block_until_ready(res.span_cycles)
    assert res.span_cycles.shape == (len(scheds), 3, 4)
    assert barrier_sim.TRACE_COUNTS["telescope_core"] == 1
    assert barrier_sim.TRACE_COUNTS["scan_core"] == 0
    # a second sweep of the same stack under new keys/delays is pure
    # data: same table shape, same widths tuple, no retrace
    res2 = sweep.sweep_schedules(jax.random.PRNGKey(4), scheds,
                                 delays=(1.0, 64.0, 512.0), n_trials=4,
                                 cfg=cfg, core="telescope")
    jax.block_until_ready(res2.span_cycles)
    assert barrier_sim.TRACE_COUNTS["telescope_core"] == 1
    # a sub-stack may tighten the width table (it is a max over the
    # stacked rows), which is a deliberate static change: at most one
    # extra trace, never one per schedule
    sub = sweep.sweep_schedules(jax.random.PRNGKey(5), scheds[:8],
                                delays=(1.0,), n_trials=2,
                                cfg=cfg, core="telescope")
    jax.block_until_ready(sub.span_cycles)
    assert barrier_sim.TRACE_COUNTS["telescope_core"] <= 2


# ---------------------------------------------------------------------------
# 2-D (schedule x kernel) sharding: mesh-shape algebra + elastic sizing.
# ---------------------------------------------------------------------------

def test_mesh_shape_prefers_schedule_axis():
    # enough schedule parallelism: kernel axis stays unsharded
    assert sweep._mesh_shape(8, 128, 2) == (8, 1)
    assert sweep._mesh_shape(4, 128, 7) == (4, 1)
    # short schedule stack: the kernel axis picks up the slack
    assert sweep._mesh_shape(8, 2, 16) == (2, 4)
    assert sweep._mesh_shape(8, 4, 8) == (4, 2)
    assert sweep._mesh_shape(8, 1, 64) == (1, 8)
    # indivisible axes: largest usable sub-mesh, (1, 1) fallback
    assert sweep._mesh_shape(8, 3, 5) == (1, 5)
    assert sweep._mesh_shape(1, 128, 16) == (1, 1)
    assert sweep._mesh_shape(8, 7, 11) == (7, 1)


def test_viable_grid_devices():
    devs = tuple(range(8))     # stand-ins: only the count matters
    assert elastic.viable_grid_devices(devs, 4, 8) == devs
    assert elastic.viable_grid_devices(devs, 128, 2) == devs
    assert elastic.viable_grid_devices(devs[:5], 4, 1) == devs[:4]
    assert elastic.viable_grid_devices(devs, 3, 5, min_devices=6) is None
    with pytest.raises(ValueError, match="kernel axis"):
        elastic.viable_grid_devices(devs, 4, 0)
    with pytest.raises(ValueError, match="schedule axis"):
        elastic.viable_grid_devices(devs, 0, 4)


def test_sharded_2d_grid_multidevice():
    """Under 8 host devices a short-schedule-stack arrival grid shards
    over the 2-D (schedule x kernel) mesh and matches the unsharded
    path bit for bit."""
    env = dict(os.environ)
    env["REPRO_MULTIDEV"] = "1"
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        + os.environ.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = str(REPO / "src")
    script = """
import jax
import jax.numpy as jnp
import numpy as np
from repro.core import barrier_sim, sweep, tuning
from repro.core.topology import TeraPoolConfig, multi_cluster

assert len(jax.devices()) == 8, jax.devices()
cfg = multi_cluster(TeraPoolConfig(n_pes=64), n_clusters=4)
scheds = tuning.multicluster_schedules(cfg)[:4]   # S=4 < 8 devices
arr = 512.0 * jax.random.uniform(jax.random.PRNGKey(0), (8, 3, 256))
# S=4, K=8 on 8 devices -> the 2-D mesh engages: (4, 2)
assert sweep._mesh_shape(8, 4, 8) == (4, 2)
barrier_sim.TRACE_COUNTS.clear()
sharded = sweep.sweep_arrivals(arr, scheds, cfg=cfg, shard=True)
jax.block_until_ready(sharded.span_cycles)
assert barrier_sim.core_traces() == 1, dict(barrier_sim.TRACE_COUNTS)
plain = sweep.sweep_arrivals(arr, scheds, cfg=cfg, shard=False)
np.testing.assert_array_equal(np.asarray(sharded.span_cycles),
                              np.asarray(plain.span_cycles))
np.testing.assert_array_equal(np.asarray(sharded.exit_time),
                              np.asarray(plain.exit_time))
# schedule-divisible stacks keep taking the 1-D path (it covers all
# devices already) and stay bit-for-bit too
scheds8 = tuning.multicluster_schedules(cfg)[:8]
s8 = sweep.sweep_arrivals(arr, scheds8, cfg=cfg, shard=True)
p8 = sweep.sweep_arrivals(arr, scheds8, cfg=cfg, shard=False)
np.testing.assert_array_equal(np.asarray(s8.span_cycles),
                              np.asarray(p8.span_cycles))
print("2d sharded sweep ok")
"""
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "2d sharded sweep ok" in r.stdout
