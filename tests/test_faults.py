"""Degradation-tolerant barriers: timeout/quorum release semantics.

Acceptance bars of the robustness PR:

* ZERO-FAULT DEGENERATION — with no fault mask, infinite timeouts and
  quorum 1.0, both robust cores return bit-for-bit the plain cores'
  results (every field) across compositions x placements.
* ORACLE EQUALITY — under fault masks, finite timeouts, per-level
  timeout rows and sub-1.0 quorums, both robust cores match the
  independent numpy walk (``simulate_robust_reference``) bit-for-bit
  at N in {64, 256, 1024}.
* ONE-COMPILE — fault masks, timeout rows and quorum fractions are
  traced data: sweeping them (directly or through the sweep/tuning
  grids) never retraces a core.
* SEMANTICS — watchdogs bound the release time of straggler-held
  levels, quorums release at ceil(q*g)-of-g, abandoned PEs are
  reported, and the energy column prices timeout polling and
  abandonment on top of the plain episode energy.
* ROBUST TUNING — the tail objectives (p99/worst/completion) select
  schedules, and under injected PE faults the p99-tuned winner is at
  least as good on p99 as the latency-tuned winner evaluated on the
  same faulted arrivals.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (barrier, barrier_sim, fiveg, placement, sweep,
                        tuning, workloads)
from repro.core.barrier import NO_FAULTS, fault_spec
from repro.core.barrier_sim import BarrierResult
from repro.core.energy import DEFAULT_ENERGY
from repro.core.topology import TeraPoolConfig

KEY = jax.random.PRNGKey(11)
CFG = TeraPoolConfig(n_pes=64)

COMPS = [(8, 8), (4, 4, 4), (2, 8, 4), (64,), (2, 2, 2, 2, 2, 2)]

SPECS = [
    fault_spec(),                                        # degenerate
    fault_spec(timeout_cycles=250.0),
    fault_spec(quorum_frac=0.75),
    fault_spec(timeout_cycles=300.0, quorum_frac=0.9),
    fault_spec(timeout_cycles=[200.0, 400.0, 800.0]),    # per-level row
]


def _arr(key, batch, n, scale=400.0):
    return jax.random.uniform(key, (batch, n), jnp.float32, 0.0, scale)


def _mask(key, batch, n, p=0.1):
    return jax.random.bernoulli(key, p, (batch, n))


def _assert_bitwise(got, want, ctx):
    for name, a, b in zip(BarrierResult._fields, got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{ctx}: {name}")


# ---------------------------------------------------------------------------
# Zero-fault degeneration: robust cores ARE the plain cores bit-for-bit.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("core", ["scan", "telescope"])
def test_zero_faults_degenerate_bitforbit(core):
    arr = _arr(KEY, 5, 64)
    for comp in COMPS:
        sched = barrier.mixed_radix_tree(comp, n_pes=64, cfg=CFG)
        placs = [None] + [placement.place_counters(sched, s, CFG)
                          for s in placement.STRATEGIES]
        for plc in placs:
            plain = barrier_sim.simulate(arr, sched, CFG, placement=plc,
                                         core=core)
            rob = barrier_sim.simulate(arr, sched, CFG, placement=plc,
                                       core=core, faults=NO_FAULTS)
            ctx = f"{comp}@{plc.strategy if plc else None}/{core}"
            for f in ("exit_time", "last_arrival", "span_cycles",
                      "mean_residency", "energy"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(plain, f)),
                    np.asarray(getattr(rob, f)), err_msg=f"{ctx}: {f}")
            assert bool(rob.completed.all()), ctx
            assert int(rob.abandoned_pes.sum()) == 0, ctx
            assert int(rob.timed_out_levels.sum()) == 0, ctx


def test_hw_event_unit_degenerates_too():
    sched = barrier.hw_event_unit(64, cfg=CFG)
    arr = _arr(KEY, 3, 64)
    for core in ("scan", "telescope"):
        plain = barrier_sim.simulate(arr, sched, CFG, core=core)
        rob = barrier_sim.simulate(arr, sched, CFG, core=core,
                                   faults=NO_FAULTS)
        np.testing.assert_array_equal(np.asarray(plain.exit_time),
                                      np.asarray(rob.exit_time))
        np.testing.assert_array_equal(np.asarray(plain.energy),
                                      np.asarray(rob.energy))


# ---------------------------------------------------------------------------
# Oracle equality: both robust cores == the independent numpy fault walk.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("core", ["scan", "telescope"])
def test_oracle_bitforbit_n64_compositions_placements(core):
    arr = _arr(KEY, 4, 64)
    mask = _mask(jax.random.fold_in(KEY, 1), 4, 64)
    for comp in [(8, 8), (4, 4, 4), (2, 8, 4)]:
        sched = barrier.mixed_radix_tree(comp, n_pes=64, cfg=CFG)
        for plc in [None,
                    placement.place_counters(sched, "central", CFG),
                    placement.place_counters(sched, "tile_interleaved",
                                             CFG)]:
            for si, spec in enumerate(SPECS):
                ref = barrier_sim.simulate_robust_reference(
                    arr, sched, CFG, placement=plc, faults=spec,
                    fault_mask=mask)
                got = barrier_sim.simulate(arr, sched, CFG, placement=plc,
                                           core=core, faults=spec,
                                           fault_mask=mask)
                _assert_bitwise(
                    got, ref,
                    f"{comp}@{plc.strategy if plc else None}/spec{si}")


@pytest.mark.parametrize("n,comp", [(256, (4, 8, 8)), (1024, (8, 8, 16))])
def test_oracle_bitforbit_large_n(n, comp):
    cfg = TeraPoolConfig(n_pes=n)
    arr = _arr(jax.random.fold_in(KEY, n), 2, n, scale=600.0)
    mask = _mask(jax.random.fold_in(KEY, n + 1), 2, n, p=0.02)
    sched = barrier.mixed_radix_tree(comp, n_pes=n, cfg=cfg)
    for spec in [fault_spec(timeout_cycles=500.0, quorum_frac=0.95),
                 fault_spec(quorum_frac=0.5)]:
        ref = barrier_sim.simulate_robust_reference(
            arr, sched, cfg, faults=spec, fault_mask=mask)
        for core in ("scan", "telescope"):
            got = barrier_sim.simulate(arr, sched, cfg, core=core,
                                       faults=spec, fault_mask=mask)
            _assert_bitwise(got, ref, f"N={n}/{core}")


def test_oracle_bitforbit_central_and_hw():
    """Single-level central counter and the hw event unit walk through
    the same oracle under faults."""
    arr = _arr(KEY, 3, 64)
    mask = _mask(jax.random.fold_in(KEY, 2), 3, 64)
    spec = fault_spec(timeout_cycles=400.0, quorum_frac=0.9)
    for sched in (barrier.central_counter(64, cfg=CFG),
                  barrier.hw_event_unit(64, cfg=CFG)):
        ref = barrier_sim.simulate_robust_reference(
            arr, sched, CFG, faults=spec, fault_mask=mask)
        for core in ("scan", "telescope"):
            got = barrier_sim.simulate(arr, sched, CFG, core=core,
                                       faults=spec, fault_mask=mask)
            _assert_bitwise(got, ref, f"{sched.radix}/{core}")


# ---------------------------------------------------------------------------
# One-compile: masks, timeouts and quorums are traced data.
# ---------------------------------------------------------------------------

def test_one_compile_across_masks_and_specs():
    sched = barrier.mixed_radix_tree((4, 4, 4), n_pes=64, cfg=CFG)
    arr = _arr(KEY, 2, 64)
    # warm both robust cores
    for core in ("scan", "telescope"):
        barrier_sim.simulate(arr, sched, CFG, core=core, faults=NO_FAULTS)
    t0 = barrier_sim.core_traces()
    for i, spec in enumerate(SPECS[:4]):
        mask = _mask(jax.random.fold_in(KEY, 10 + i), 2, 64, p=0.05 * i)
        for core in ("scan", "telescope"):
            barrier_sim.simulate(arr, sched, CFG, core=core, faults=spec,
                                 fault_mask=mask)
    assert barrier_sim.core_traces() == t0, \
        "fault mask / timeout / quorum sweep retraced a core"


def test_one_compile_robust_sweep_grids():
    scheds = [barrier.mixed_radix_tree(c, n_pes=64, cfg=CFG)
              for c in [(8, 8), (4, 4, 4)]]
    sweep.sweep_schedules(KEY, scheds, (0.0, 128.0), n_trials=4, cfg=CFG,
                          faults=fault_spec(timeout_cycles=200.0))
    arrs = _arr(jax.random.fold_in(KEY, 3), 4, 64)[None]
    sweep.sweep_arrivals(arrs, scheds, CFG,
                         faults=fault_spec(quorum_frac=0.8))
    t0 = barrier_sim.core_traces()
    sweep.sweep_schedules(KEY, scheds, (64.0, 512.0), n_trials=4, cfg=CFG,
                          faults=fault_spec(timeout_cycles=900.0,
                                            quorum_frac=0.6))
    sweep.sweep_arrivals(arrs * 0.5, scheds, CFG,
                         faults=fault_spec(quorum_frac=0.95))
    assert barrier_sim.core_traces() == t0


# ---------------------------------------------------------------------------
# Semantics: watchdog bounds, quorum counts, abandonment, energy prices.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("core", ["scan", "telescope"])
def test_timeout_bounds_straggler_hold(core):
    """One PE arrives 1e6 cycles late.  Without a watchdog the barrier
    waits for it; with one, the release is bounded near the deadline
    and exactly one PE is abandoned."""
    sched = barrier.mixed_radix_tree((8, 8), n_pes=64, cfg=CFG)
    arr = jnp.zeros((64,), jnp.float32).at[17].set(1e6)
    slow = barrier_sim.simulate(arr, sched, CFG, core=core,
                                faults=NO_FAULTS)
    fast = barrier_sim.simulate(arr, sched, CFG, core=core,
                                faults=fault_spec(timeout_cycles=100.0))
    assert float(slow.exit_time) > 1e6
    assert float(fast.exit_time) < 1000.0
    assert int(fast.abandoned_pes) == 1
    assert int(fast.timed_out_levels) >= 1
    assert bool(fast.completed)


@pytest.mark.parametrize("core", ["scan", "telescope"])
def test_quorum_releases_k_of_n(core):
    """With quorum 0.5 on a single 64-wide level, the release tracks
    the 32nd arrival, not the last: stragglers beyond the quorum are
    abandoned without any watchdog."""
    sched = barrier.central_counter(64, cfg=CFG)
    arr = jnp.concatenate([jnp.zeros(32), jnp.full((32,), 1e5)]
                          ).astype(jnp.float32)
    res = barrier_sim.simulate(arr, sched, CFG, core=core,
                               faults=fault_spec(quorum_frac=0.5))
    assert float(res.exit_time) < 1e4
    assert int(res.abandoned_pes) == 32
    assert int(res.timed_out_levels) == 0     # quorum, not watchdog
    full = barrier_sim.simulate(arr, sched, CFG, core=core,
                                faults=NO_FAULTS)
    assert float(full.exit_time) > 1e5


@pytest.mark.parametrize("core", ["scan", "telescope"])
def test_fail_stop_mask_abandons_and_releases(core):
    """Fail-stop PEs (+inf arrivals) are abandoned at entry; the
    survivors release at the watchdog deadline and the episode still
    completes with a finite exit."""
    sched = barrier.mixed_radix_tree((8, 8), n_pes=64, cfg=CFG)
    arr = jnp.zeros((64,), jnp.float32)
    mask = jnp.zeros((64,), bool).at[jnp.asarray([3, 40, 41])].set(True)
    res = barrier_sim.simulate(arr, sched, CFG, core=core,
                               faults=fault_spec(timeout_cycles=50.0),
                               fault_mask=mask)
    assert bool(res.completed)
    assert np.isfinite(float(res.exit_time))
    assert int(res.abandoned_pes) == 3
    # without a release policy the same mask hangs the barrier
    hung = barrier_sim.simulate(arr, sched, CFG, core=core,
                                fault_mask=mask)
    assert not np.isfinite(float(hung.exit_time))
    assert not bool(hung.completed)


def test_robust_energy_prices_timeouts_and_abandonment():
    """energy == plain episode energy + e_timeout_poll * timed levels
    + e_abandon * abandoned PEs, on the shared accounting helper."""
    sched = barrier.mixed_radix_tree((8, 8), n_pes=64, cfg=CFG)
    arr = jnp.zeros((64,), jnp.float32).at[17].set(1e6)
    res = barrier_sim.simulate(arr, sched, CFG, core="scan",
                               faults=fault_spec(timeout_cycles=100.0))
    consts = barrier_sim.schedule_energy_constants(sched, None, CFG,
                                                   DEFAULT_ENERGY)
    from repro.core.energy import episode_energy
    base = episode_energy(consts[0], consts[1], consts[2], 64,
                          res.mean_residency)
    want = (float(base)
            + DEFAULT_ENERGY.e_timeout_poll * float(res.timed_out_levels)
            + DEFAULT_ENERGY.e_abandon * float(res.abandoned_pes))
    assert float(res.energy) == pytest.approx(want, rel=1e-6)


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="timeout_cycles"):
        fault_spec(timeout_cycles=-1.0)
    with pytest.raises(ValueError, match="quorum_frac"):
        fault_spec(quorum_frac=0.0)
    with pytest.raises(ValueError, match="quorum_frac"):
        fault_spec(quorum_frac=1.5)


# ---------------------------------------------------------------------------
# Robust tuning: tail objectives + completion under injected faults.
# ---------------------------------------------------------------------------

def test_tail_objectives_select_and_order():
    res = tuning.tune_barrier(KEY, 64, delays=(256.0,), n_trials=16,
                              cfg=CFG, prune="hierarchy")
    mean = jnp.mean(res.span_cycles, axis=-1)
    for obj in ("p99_cycles", "worst_cycles", "completion"):
        grid = tuning._objective_grid(res, obj)
        assert grid.shape == mean.shape
    # fault-free sweeps: nothing abandoned, mean <= p99 <= worst
    assert float(jnp.max(tuning._objective_grid(res, "completion"))) == 0.0
    p99 = tuning._objective_grid(res, "p99_cycles")
    worst = tuning._objective_grid(res, "worst_cycles")
    assert bool(jnp.all(mean <= p99 + 1e-3))
    assert bool(jnp.all(p99 <= worst + 1e-3))
    with pytest.raises(ValueError, match="unknown objective"):
        tuning._objective_grid(res, "p50")


def test_robust_tuning_beats_latency_winner_on_p99_under_faults():
    """The acceptance bar in miniature: inject fail-stop faults +
    straggler tails into a workload sweep; the p99-tuned schedule must
    be at least as good on p99 as the fault-free latency winner,
    evaluated on the SAME faulted arrivals."""
    model = workloads.PEFaultModel(p_fail=0.02, p_straggler=0.1,
                                   straggler_scale=2000.0)
    spec = fault_spec(timeout_cycles=1500.0, quorum_frac=0.95)
    clean = tuning.sweep_workloads(KEY, ("dotp_1Mi",), 64, n_trials=16,
                                   cfg=CFG, prune="hierarchy")
    faulted = tuning.sweep_workloads(KEY, ("dotp_1Mi",), 64, n_trials=16,
                                     cfg=CFG, prune="hierarchy",
                                     faults=spec, fault_model=model)
    assert clean.schedules == faulted.schedules
    lat_i = int(jnp.argmin(tuning._objective_grid(clean, "cycles")[:, 0]))
    p99_grid = tuning._objective_grid(faulted, "p99_cycles")[:, 0]
    rob_i = int(jnp.argmin(p99_grid))
    assert float(p99_grid[rob_i]) <= float(p99_grid[lat_i])
    # faults actually bit: some episodes abandoned PEs
    assert float(jnp.max(faulted.abandoned_pes)) > 0
    assert float(jnp.min(faulted.completion_rate)) < 1.0
    # fault-free draws are identical with and without the model hook
    same = tuning.sweep_workloads(KEY, ("dotp_1Mi",), 64, n_trials=16,
                                  cfg=CFG, prune="hierarchy",
                                  fault_model=workloads.NO_PE_FAULTS)
    np.testing.assert_array_equal(np.asarray(same.span_cycles),
                                  np.asarray(clean.span_cycles))


# ---------------------------------------------------------------------------
# 5G under PE failures: finite, degrading, one compile across rates.
# ---------------------------------------------------------------------------

def test_fiveg_faults_mode_smoke():
    app = fiveg.FiveGConfig(n_rx=16, ffts_per_round=1)
    key = jax.random.PRNGKey(5)
    plain = fiveg.simulate_app(key, app, sync="tree", radix=32,
                               core="scan")
    rob0 = fiveg.simulate_app(
        key, app, sync="tree", radix=32, core="scan",
        faults=fiveg.FiveGFaults(fail_rate=0.0,
                                 timeout_cycles=float("inf")))
    assert float(plain.total_cycles) == float(rob0.total_cycles)
    assert float(rob0.completion_rate) == 1.0

    t0 = barrier_sim.core_traces()
    res = fiveg.simulate_app(
        key, app, sync="tree", radix=32, core="scan",
        faults=fiveg.FiveGFaults(fail_rate=0.02, timeout_cycles=2000.0,
                                 seed=3))
    assert barrier_sim.core_traces() == t0    # mask/spec are traced data
    assert np.isfinite(float(res.total_cycles))
    assert float(res.completion_rate) < 1.0
    assert float(res.total_cycles) >= float(plain.total_cycles)
    with pytest.raises(ValueError, match="fail_rate"):
        fiveg.FiveGFaults(fail_rate=1.5)
