"""Workload-conditioned tuning: data-dependent arrival sweeps through
the one-compile engine (trace-counted at N=256 across kernels x
schedules x placements x trials), bit-for-bit equivalence with the seed
oracle, the acceptance bar that per-kernel tuning matches or beats
per-delay tuning on every Fig. 6 kernel (superset construction), the
lru-cached schedule store, and the 5G ``sync="workload"`` mode with
per-epoch specialized schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (barrier, barrier_sim, fiveg, placement, sweep,
                        tuning, workloads)

KEY = jax.random.PRNGKey(0)
DELAYS = (0.0, 128.0, 512.0, 2048.0)


# ---------------------------------------------------------------------------
# sweep_arrivals: the data-dependent grid == the seed per-level oracle.
# ---------------------------------------------------------------------------

def test_sweep_arrivals_matches_oracle():
    arr = jnp.stack([
        workloads.arrival_batch(KEY, "dotp_1Mi", (2, 256)),
        workloads.arrival_batch(jax.random.PRNGKey(1), "conv2d_256x256",
                                (2, 256)),
    ])                                                   # (K=2, T=2, 256)
    scheds = [barrier.kary_tree(r, n_pes=256) for r in (2, 16, 256)] + \
        [barrier.mixed_radix_tree((8, 16, 2))]
    res = sweep.sweep_arrivals(arr, scheds, kernels=("dotp", "conv2d"))
    assert res.span_cycles.shape == (4, 2, 2)
    assert res.kernels == ("dotp", "conv2d")
    for i, s in enumerate(scheds):
        for k in range(2):
            for t in range(2):
                ref = barrier_sim.simulate_reference(arr[k, t], s)
                got = (res.exit_time[i, k, t], res.last_arrival[i, k, t],
                       res.span_cycles[i, k, t],
                       res.mean_residency[i, k, t])
                for name, a, b in zip(ref._fields, got, ref):
                    assert float(a) == float(b), (s.name, k, t, name)


def test_sweep_arrivals_single_workload_and_validation():
    arr = workloads.arrival_batch(KEY, "axpy_1Mi", (3, 64))   # (T, N)
    scheds = [barrier.kary_tree(r, n_pes=64) for r in (2, 64)]
    res = sweep.sweep_arrivals(arr, scheds)
    assert res.span_cycles.shape == (2, 1, 3)
    assert res.kernels == ("workload0",)
    with pytest.raises(ValueError):       # PE-width mismatch
        sweep.sweep_arrivals(arr, [barrier.kary_tree(2, n_pes=128)])
    with pytest.raises(ValueError):       # name count mismatch
        sweep.sweep_arrivals(arr[None], scheds, kernels=("a", "b"))
    with pytest.raises(ValueError):       # 1-D arrivals
        sweep.sweep_arrivals(arr[0, :], scheds)


# ---------------------------------------------------------------------------
# Acceptance: one compile across kernels x schedules x placements x
# trials at N=256.
# ---------------------------------------------------------------------------

def test_workload_sweep_compiles_once_n256():
    """Every Fig. 6 kernel x the hierarchy-pruned composition space x
    every placement strategy x trials traces the scanned core exactly
    once."""
    jax.clear_caches()
    barrier_sim.TRACE_COUNTS.clear()
    res = tuning.sweep_workloads(jax.random.PRNGKey(9), n_pes=256,
                                 n_trials=2, prune="hierarchy",
                                 placements=placement.STRATEGIES)
    jax.block_until_ready(res.span_cycles)
    # 32 hierarchy compositions x 4 strategies, 15 kernels, 2 trials.
    assert res.span_cycles.shape == (128, 15, 2)
    assert res.kernels == workloads.FIG6_KERNELS
    assert barrier_sim.core_traces() == 1

    # A second sweep with different arrivals reuses the compile.
    res2 = tuning.sweep_workloads(jax.random.PRNGKey(10), n_pes=256,
                                  n_trials=2, prune="hierarchy",
                                  placements=placement.STRATEGIES)
    jax.block_until_ready(res2.span_cycles)
    assert barrier_sim.core_traces() == 1


# ---------------------------------------------------------------------------
# Acceptance: per-kernel tuned >= per-delay tuned, exactly, on every
# Fig. 6 kernel (superset construction).
# ---------------------------------------------------------------------------

def test_workload_tuned_matches_or_beats_delay_tuned():
    """The workload tuner evaluates the FULL composition stack on each
    kernel's own arrivals and takes the argmin, so its span can only
    match or beat (a) the best uniform radix and (b) whatever
    best_per_delay selected from uniform scatters — evaluated on the
    same arrivals — for EVERY Fig. 6 kernel."""
    n = 256
    schedules = tuning.all_schedules(n)
    dres = tuning.tune_barrier(KEY, n, delays=DELAYS, n_trials=4,
                               schedules=schedules)
    delay_winners = {p.schedule for p in tuning.best_per_delay(dres)}
    wres = tuning.sweep_workloads(KEY, n_pes=n, n_trials=4,
                                  schedules=schedules)
    spans = np.asarray(wres.mean_span)                  # (S, K)
    points = tuning.best_per_kernel(wres)
    assert [p.kernel for p in points] == list(workloads.FIG6_KERNELS)
    for j, p in enumerate(points):
        assert p.mean_span <= p.uniform_span, p.kernel
        for w in delay_winners:
            i = wres.schedules.index(w)
            assert p.mean_span <= float(spans[i, j]), (p.kernel, w.name)


def test_tune_for_workload_and_cached_store():
    p = tuning.tune_for_workload(KEY, "dotp_1Mi", n_pes=64, n_trials=4)
    assert p.kernel == "dotp_1Mi"
    assert p.schedule.n_pes == 64
    assert p.mean_span <= p.uniform_span
    assert p.placement is None                   # placement-free stack

    tuning.tuned_for_workload.cache_clear()
    s1, pl1 = tuning.tuned_for_workload("conv2d_128x128", 64)
    s2, pl2 = tuning.tuned_for_workload("conv2d_128x128", 64)
    assert s1 == s2 and pl1 == pl2
    assert tuning.tuned_for_workload.cache_info().hits == 1
    assert s1.n_pes == 64

    # the joint (schedule, placement) optimum: leaf-local dominates
    # in-model, so the placed workload winner is never contended
    s3, pl3 = tuning.tuned_for_workload("dotp_1Mi", 64,
                                        placements=placement.STRATEGIES)
    assert pl3 is not None
    assert pl3.shared_bank_counters() == (0,) * s3.n_levels


def test_tune_for_arrivals_explicit_matrix():
    arr = workloads.arrival_batch(KEY, "dct_2x4096", (4, 64))
    sched, plc, span = tuning.tune_for_arrivals(arr)
    assert sched.n_pes == 64 and plc is None and span > 0
    # the returned span is the argmin over the evaluated stack
    res = sweep.sweep_arrivals(arr, tuning.all_schedules(64))
    assert span == pytest.approx(float(jnp.min(res.mean_span)), rel=1e-6)
    with pytest.raises(ValueError):
        tuning.tune_for_arrivals(jnp.zeros((2, 3, 64)))


# ---------------------------------------------------------------------------
# The 5G sync="workload" mode: per-epoch specialization.
# ---------------------------------------------------------------------------

def test_5g_workload_mode_at_design_point():
    """At the paper's 4x16-FFT design point the per-epoch workload
    specialization must synchronize no worse than the uniform-proxy
    joint tuner: sync fraction <= sync="placed" (the acceptance bar),
    and the winning per-epoch schedules are exposed for reporting."""
    app = fiveg.FiveGConfig()                    # n_rx=64, 4 FFTs/round
    res = fiveg.compare_barriers(
        KEY, app, radix=32, modes=("central", "placed", "workload"))
    w, p = res["workload"], res["placed"]
    assert float(w.sync_fraction) <= float(p.sync_fraction)
    assert float(res["speedup_workload"]) > 1.0
    # exposed per-epoch winners: stage and global tuned separately
    assert w.stage_schedule and w.global_schedule
    assert "@" in w.stage_schedule               # joint placement tuned
    # every mode reports its schedules, not only the tuned ones
    assert res["central"].stage_schedule == "1024"


def test_5g_workload_scanned_matches_unrolled():
    app = fiveg.FiveGConfig(n_rx=16, ffts_per_round=1)
    got = fiveg.simulate_app(KEY, app, sync="workload")
    ref = fiveg.simulate_app_reference(KEY, app, sync="workload")
    for name, a, b in zip(got._fields, got, ref):
        if isinstance(a, str):   # winning-schedule names, not timings
            assert a == b and a, name
            continue
        assert float(a) == pytest.approx(float(b), rel=1e-5), name
