"""Padded level-table simulator + sweep engine: equivalence against the
seed per-level oracle, and the one-compile property of the full grid."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import barrier, barrier_sim, fiveg, sweep
from repro.core.topology import DEFAULT

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Level tables.
# ---------------------------------------------------------------------------

def test_level_table_padding_and_values():
    s = barrier.kary_tree(8)           # levels [2, 8, 8, 8] over 1024
    t = barrier.level_table(s)
    assert t.max_levels == 10          # log2(1024)
    np.testing.assert_array_equal(
        np.asarray(t.group_sizes), [2, 8, 8, 8] + [1] * 6)
    assert np.all(np.asarray(t.latencies)[4:] == 0.0)
    assert np.all(np.asarray(t.instr_cycles)[4:] == 0.0)
    assert np.all(np.asarray(t.instr_cycles)[:4]
                  == DEFAULT.instr_per_level)


def test_stack_tables_shape_and_mismatch():
    scheds = [barrier.kary_tree(r) for r in (2, 32, 1024)]
    stacked = barrier.stack_tables(scheds)
    assert stacked.group_sizes.shape == (3, 10)
    with pytest.raises(ValueError):
        barrier.stack_tables([barrier.kary_tree(2, n_pes=64),
                              barrier.kary_tree(2, n_pes=128)])


# ---------------------------------------------------------------------------
# Scanned simulate == seed per-level oracle, bit for bit.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_pes", [64, 256, 1024])
def test_scan_matches_oracle_all_radices(n_pes):
    for radix in barrier.all_radices(n_pes):
        sched = barrier.kary_tree(radix, n_pes=n_pes)
        for delay in (0.0, 37.5, 2048.0):
            arr = delay * jax.random.uniform(KEY, (n_pes,))
            got = barrier_sim.simulate(arr, sched)
            ref = barrier_sim.simulate_reference(arr, sched)
            for name, a, b in zip(got._fields, got, ref):
                assert float(a) == float(b), (n_pes, radix, delay, name)


def test_scan_matches_oracle_batched():
    sched = barrier.kary_tree(16)
    arr = 500.0 * jax.random.uniform(KEY, (3, 5, 1024))
    got = barrier_sim.simulate(arr, sched)
    ref = barrier_sim.simulate_reference(arr, sched)
    assert got.exit_time.shape == (3, 5)
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mean_residency_batched_definition():
    """Regression for the residency definition mismatch: the scanned
    core and the reference oracle must share one mean_residency formula
    (``exit_time[..., None] - arrivals``) under batched leading
    shapes, not a scalar-vs-broadcast pair that happens to agree on
    single episodes."""
    sched = barrier.mixed_radix_tree((8, 16, 8))
    arr = 2048.0 * jax.random.uniform(KEY, (4, 3, 1024))
    got = barrier_sim.simulate(arr, sched)
    ref = barrier_sim.simulate_reference(arr, sched)
    assert got.mean_residency.shape == ref.mean_residency.shape == (4, 3)
    np.testing.assert_array_equal(np.asarray(got.mean_residency),
                                  np.asarray(ref.mean_residency))
    # per-episode mean over PEs of (exit - own arrival), by definition
    one = barrier_sim.simulate(arr[0, 0], sched)
    want = float(jnp.mean(one.exit_time - arr[0, 0]))
    assert float(got.mean_residency[0, 0]) == pytest.approx(want, rel=1e-6)


def test_simulate_rejects_wrong_width():
    with pytest.raises(ValueError):
        barrier_sim.simulate(jnp.zeros(100), barrier.kary_tree(2))


# ---------------------------------------------------------------------------
# Sweep engine: grid values == per-point seed path; one compile total.
# ---------------------------------------------------------------------------

def test_sweep_grid_matches_pointwise():
    delays = (0.0, 128.0, 2048.0)
    res = sweep.sweep_barrier(KEY, radices=(2, 32, 1024), delays=delays,
                              n_trials=8)
    spans = np.asarray(res.mean_span)
    for i, radix in enumerate((2, 32, 1024)):
        sched = barrier.kary_tree(radix)
        for j, delay in enumerate(delays):
            ref = float(barrier_sim.mean_span_cycles(KEY, sched, delay,
                                                     n_trials=8))
            assert spans[i, j] == pytest.approx(ref, rel=1e-6), (radix,
                                                                 delay)


def test_full_fig4a_grid_compiles_once():
    """The acceptance-criterion grid — all radices x 4 delays x 16
    trials — traces the scanned core exactly once."""
    jax.clear_caches()
    barrier_sim.TRACE_COUNTS.clear()
    res = sweep.sweep_barrier(
        jax.random.PRNGKey(42), delays=(0.0, 128.0, 512.0, 2048.0),
        n_trials=16)
    jax.block_until_ready(res.span_cycles)
    assert res.span_cycles.shape == (10, 4, 16)
    assert barrier_sim.core_traces() == 1

    # A second call with different trace-compatible inputs reuses the
    # compiled program: no new traces at all.
    res2 = sweep.sweep_barrier(
        jax.random.PRNGKey(7), delays=(64.0, 256.0, 1024.0, 4096.0),
        n_trials=16)
    jax.block_until_ready(res2.span_cycles)
    assert barrier_sim.core_traces() == 1


def test_simulate_radices_matches_oracle():
    radices = (2, 8, 64, 1024)
    arr = 300.0 * jax.random.uniform(KEY, (1024,))
    res = sweep.simulate_radices(arr, radices)
    for i, radix in enumerate(radices):
        ref = barrier_sim.simulate_reference(arr, barrier.kary_tree(radix))
        assert float(res.exit_time[i]) == float(ref.exit_time), radix


def test_best_radix_per_delay_shape():
    res = sweep.sweep_barrier(KEY, radices=(2, 16, 1024),
                              delays=(0.0, 2048.0), n_trials=8)
    best = np.asarray(sweep.best_radix_per_delay(res))
    assert best.shape == (2,)
    assert set(best) <= {2, 16, 1024}
    # paper shape: scattered arrivals favour the central counter
    assert best[1] == 1024


# ---------------------------------------------------------------------------
# Scanned 5G app == unrolled oracle, per sync mode.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["central", "tree", "partial"])
def test_scanned_app_matches_unrolled(mode):
    key = jax.random.PRNGKey(3)
    app = fiveg.FiveGConfig(n_rx=16, ffts_per_round=1)
    got = fiveg.simulate_app(key, app, sync=mode, radix=32)
    ref = fiveg.simulate_app_reference(key, app, sync=mode, radix=32)
    for name, a, b in zip(got._fields, got, ref):
        if isinstance(a, str):   # winning-schedule names, not timings
            assert a == b and a, (mode, name)
            continue
        assert float(a) == pytest.approx(float(b), rel=1e-6), (mode, name)


def test_app_radix_sweep_does_not_retrace():
    key = jax.random.PRNGKey(5)
    app = fiveg.FiveGConfig(n_rx=16, ffts_per_round=1)
    fiveg.simulate_app(key, app, sync="tree", radix=32)   # warm the cache
    barrier_sim.TRACE_COUNTS.clear()
    for radix in (2, 8, 64, 256):
        fiveg.simulate_app(key, app, sync="tree", radix=radix)
    assert barrier_sim.core_traces() == 0
